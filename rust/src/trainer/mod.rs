//! Per-trainer state: the trainer's outer parameters, its M workers
//! (each with persistent inner-optimizer state, a data sub-shard, a node
//! assignment and a virtual clock slot), the adaptive-batch controller,
//! and the outer optimizer.
//!
//! Lifecycle per outer step (Algorithm 3): workers copy the trainer's
//! parameters (line 30), run H inner steps on their shard, then the
//! trainer reduces the worker deltas (line 42) and applies the outer
//! optimizer (line 43). The controller's `requested()` is the b_req the
//! trainer "stores for the next outer step" (line 32).

use crate::batching::BatchController;
use crate::config::AlgoConfig;
use crate::data::{BatchSampler, Shard};
use crate::engine::{ModelState, TrainEngine};
use crate::outer::OuterOpt;
use crate::util::Rng;

/// One worker (the paper's m ∈ T_i): inner-loop executor.
pub struct Worker {
    /// Model + inner-optimizer state. Parameters are overwritten from the
    /// trainer at each outer step; AdamW moments persist across outer
    /// steps (standard DiLoCo practice).
    pub state: ModelState,
    /// This worker's epoch-shuffled view of its data sub-shard.
    pub sampler: BatchSampler,
    /// Node (simulated GPU) this worker runs on.
    pub node: usize,
    /// Slot in the run-wide VirtualClock.
    pub clock_slot: usize,
    /// Private stream for engine gradient/loss noise. Per-worker streams
    /// make the numeric trajectory independent of scheduling order — the
    /// property the event-driven scheduler's bit-identity rests on
    /// (DESIGN.md §3.4).
    pub noise_rng: Rng,
    /// Private stream for compute-time perturbations (legacy step jitter
    /// and scenario straggler draws).
    pub time_rng: Rng,
    /// False while this worker's node is preempted by a churn scenario;
    /// inactive workers sit out whole outer steps. Always true under a
    /// static scenario.
    pub active: bool,
}

/// One trainer (the paper's T_i): a model instance spanning M workers.
pub struct Trainer {
    /// Trainer id (position in the coordinator's pool).
    pub id: usize,
    /// Outer parameters x_{T_i}.
    pub params: Vec<f32>,
    /// Outer optimizer (per-trainer state).
    pub outer: OuterOpt,
    /// Adaptive-batching controller.
    pub controller: BatchController,
    /// The trainer's M workers.
    pub workers: Vec<Worker>,
    /// The trainer's data shard (workers partition it).
    pub shard: Shard,
    /// Dead trainers were consumed by a merge and take no further part.
    pub alive: bool,
    /// Inner steps this trainer has executed (per worker, they advance in
    /// lockstep inside an outer step).
    pub inner_steps_done: u64,
}

impl Trainer {
    /// Build a trainer with `workers` workers over `shard`, placing worker
    /// j on `nodes[(base_worker + j) % nodes.len()]`-style assignment done
    /// by the caller (the coordinator owns placement).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        engine: &dyn TrainEngine,
        algo: &AlgoConfig,
        shard: Shard,
        node_of_worker: &[usize],
        clock_base: usize,
        init_seed: u64,
        rng: &mut Rng,
    ) -> Trainer {
        let m = algo.workers_per_trainer;
        assert_eq!(node_of_worker.len(), m);
        let trainer_state = engine.init_state(init_seed);
        let worker_shards = shard.split(m);
        let workers = worker_shards
            .into_iter()
            .enumerate()
            .map(|(j, ws)| Worker {
                state: ModelState::zeros_like(trainer_state.params.clone()),
                sampler: BatchSampler::new(ws, rng.fork(id as u64 * 1024 + j as u64)),
                node: node_of_worker[j],
                clock_slot: clock_base + j,
                noise_rng: rng.fork(0x4015E ^ (id as u64 * 1024 + j as u64)),
                time_rng: rng.fork(0x71EE ^ (id as u64 * 1024 + j as u64)),
                active: true,
            })
            .collect();
        Trainer {
            id,
            params: trainer_state.params,
            outer: OuterOpt::new(algo.outer_opt, algo.lr_outer, engine.param_count()),
            controller: BatchController::new(algo.batching.clone()),
            workers,
            shard,
            alive: true,
            inner_steps_done: 0,
        }
    }

    /// Build a mid-run **spawned** instance (the elastic lifecycle,
    /// DESIGN.md §9): a lightweight stream whose outer parameters start
    /// from `params` (the last merge product or the global model), whose
    /// workers all sit on one `node` with pre-allocated `clock_slots`,
    /// and whose every stochastic stream forks from the caller's
    /// instance-private `rng` (seeded via
    /// `derive_seed(cfg.seed, "instance=<id>")`) — never from the
    /// coordinator's main stream, so existing instances replay
    /// bit-for-bit whether or not the spawn happened.
    pub fn spawned(
        id: usize,
        params: Vec<f32>,
        algo: &AlgoConfig,
        shard: Shard,
        node: usize,
        clock_slots: &[usize],
        rng: &mut Rng,
    ) -> Trainer {
        let m = clock_slots.len();
        assert!(m >= 1, "a spawned instance needs at least one worker");
        let worker_shards = shard.split(m);
        let workers = worker_shards
            .into_iter()
            .enumerate()
            .map(|(j, ws)| Worker {
                state: ModelState::zeros_like(params.clone()),
                sampler: BatchSampler::new(ws, rng.fork(0x5BA7 ^ j as u64)),
                node,
                clock_slot: clock_slots[j],
                noise_rng: rng.fork(0x4015E ^ j as u64),
                time_rng: rng.fork(0x71EE ^ j as u64),
                active: true,
            })
            .collect();
        let p = params.len();
        Trainer {
            id,
            params,
            outer: OuterOpt::new(algo.outer_opt, algo.lr_outer, p),
            controller: BatchController::new(algo.batching.clone()),
            workers,
            shard,
            alive: true,
            inner_steps_done: 0,
        }
    }

    /// Outer-step prologue: every worker restarts from the trainer params
    /// (Algorithm 3 line 30).
    pub fn broadcast_params(&mut self) {
        for w in &mut self.workers {
            w.state.params.copy_from_slice(&self.params);
        }
    }

    /// Outer-step epilogue: Δ = x_prev − mean(workers), outer-opt step
    /// (Algorithm 3 lines 40-44). `delta_scratch` avoids reallocation.
    /// Outside event-scheduler churn every worker is active, so this is
    /// exactly the all-workers reduction.
    pub fn outer_step(&mut self, delta_scratch: &mut [f32]) {
        self.outer_step_active(delta_scratch)
    }

    /// Δ = x − mean(active workers) into `delta`; returns false (and
    /// leaves `delta` untouched) when the whole cohort is preempted.
    /// The single implementation behind both the blocking epilogue
    /// ([`Self::outer_step_active`]) and the delayed-overlap post
    /// (DESIGN.md §8), so the two cannot drift.
    pub fn active_delta(&self, delta: &mut [f32]) -> bool {
        let worker_params: Vec<&[f32]> = self
            .workers
            .iter()
            .filter(|w| w.active)
            .map(|w| w.state.params.as_slice())
            .collect();
        if worker_params.is_empty() {
            return false;
        }
        OuterOpt::compute_delta(&self.params, &worker_params, delta);
        true
    }

    /// The reduction over *active* workers only — churned-out workers'
    /// stale parameters are excluded from the average. No-op if the
    /// whole cohort is preempted.
    pub fn outer_step_active(&mut self, delta_scratch: &mut [f32]) {
        if !self.active_delta(delta_scratch) {
            return;
        }
        self.outer.step(&mut self.params, delta_scratch);
    }

    /// Requested batch this trainer reports to CheckMerge.
    pub fn requested_batch(&self) -> usize {
        self.controller.requested()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::data::make_shards;
    use crate::engine::{MockEngine, MockSpec};

    fn setup(m: usize) -> (MockEngine, Trainer) {
        let engine = MockEngine::new(MockSpec { dim: 50, ..MockSpec::default() });
        let mut algo = presets::mock_default().algo;
        algo.workers_per_trainer = m;
        let mut rng = Rng::new(0);
        let shard = make_shards(100, 1, 1.0, &mut rng).pop().unwrap();
        let nodes: Vec<usize> = (0..m).map(|j| j % 2).collect();
        let t = Trainer::new(0, &engine, &algo, shard, &nodes, 0, 1, &mut rng);
        (engine, t)
    }

    #[test]
    fn construction_layout() {
        let (engine, t) = setup(3);
        assert_eq!(t.workers.len(), 3);
        assert_eq!(t.params.len(), engine.param_count());
        assert_eq!(t.workers[2].clock_slot, 2);
        assert_eq!(t.workers[2].node, 0);
        // worker shards partition the trainer shard
        let total: usize = t.workers.iter().map(|w| w.sampler.shard_len()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn spawned_instance_starts_from_given_params_with_fresh_state() {
        let algo = presets::mock_default().algo;
        let mut main_rng = Rng::new(9);
        let before = main_rng.state();
        let mut inst_rng = Rng::new(crate::util::derive_seed(0, "instance=5"));
        let shard = Shard { indices: (0..30).collect() };
        let params = vec![0.5f32; 40];
        let t = Trainer::spawned(5, params.clone(), &algo, shard, 2, &[8, 9], &mut inst_rng);
        assert_eq!(t.id, 5);
        assert!(t.alive);
        assert_eq!(t.params, params);
        assert_eq!(t.inner_steps_done, 0);
        assert_eq!(t.workers.len(), 2);
        assert_eq!(t.workers[0].clock_slot, 8);
        assert_eq!(t.workers[1].clock_slot, 9);
        for w in &t.workers {
            assert_eq!(w.node, 2, "lightweight stream: all workers on one node");
            assert!(w.active);
            assert_eq!(w.state.params, params, "zeros_like starts from the seed params");
            assert!(w.state.m.iter().all(|&x| x == 0.0), "fresh AdamW moments");
        }
        let total: usize = t.workers.iter().map(|w| w.sampler.shard_len()).sum();
        assert_eq!(total, 30, "workers partition the spawned shard");
        // the spawn never touched the coordinator-style main stream
        assert_eq!(main_rng.state(), before);
    }

    #[test]
    fn broadcast_copies_params() {
        let (_, mut t) = setup(2);
        t.params[0] = 123.0;
        t.broadcast_params();
        for w in &t.workers {
            assert_eq!(w.state.params[0], 123.0);
        }
    }

    #[test]
    fn active_delta_guards_fully_preempted_cohorts() {
        let (_, mut t) = setup(2);
        t.broadcast_params();
        let mut scratch = vec![7.0f32; t.params.len()];
        for w in &mut t.workers {
            w.active = false;
        }
        assert!(!t.active_delta(&mut scratch), "no active workers -> no delta");
        assert_eq!(scratch[0], 7.0, "scratch untouched on the guard path");
        let before = t.params[0];
        t.outer_step_active(&mut scratch); // must be a clean no-op
        assert_eq!(t.params[0], before);
        t.workers[1].active = true;
        t.workers[1].state.params[0] = t.params[0] + 4.0;
        assert!(t.active_delta(&mut scratch));
        assert!((scratch[0] + 4.0).abs() < 1e-6, "delta over the active worker only");
    }

    #[test]
    fn outer_step_average_moves_toward_workers() {
        let (_, mut t) = setup(2);
        // make outer opt a plain average for a deterministic check
        t.outer = OuterOpt::new(crate::config::OuterOptKind::Average, 1.0, t.params.len());
        t.broadcast_params();
        for w in &mut t.workers {
            w.state.params[0] += 2.0;
        }
        let prev = t.params[0];
        let mut scratch = vec![0.0f32; t.params.len()];
        t.outer_step(&mut scratch);
        assert!((t.params[0] - (prev + 2.0)).abs() < 1e-5);
    }
}
