//! Minimal benchmarking toolkit (no `criterion` in the offline crate set).
//!
//! Provides warmup+repeat timing with median/p10/p90 reporting, simple
//! table printing for the figure/table reproduction benches, CSV output
//! under `bench_results/` so every paper artifact regeneration leaves a
//! machine-readable trace, and — for the fig1/fig2 grids — the shared
//! work-stealing [`run_cells`] fan-out (one implementation for bench
//! grids, sweep cells and the coordinator's worker chains —
//! DESIGN.md §6).

use std::io::Write;
use std::time::Instant;

/// Timing summary over repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    /// Measured repetitions.
    pub reps: usize,
    /// Median seconds per repetition.
    pub median_s: f64,
    /// 10th-percentile seconds.
    pub p10_s: f64,
    /// 90th-percentile seconds.
    pub p90_s: f64,
    /// Mean seconds per repetition.
    pub mean_s: f64,
}

impl Timing {
    /// Repetitions per second at the median.
    pub fn per_sec(&self) -> f64 {
        if self.median_s > 0.0 {
            1.0 / self.median_s
        } else {
            f64::INFINITY
        }
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "median {:.3}ms  p10 {:.3}ms  p90 {:.3}ms  ({} reps)",
            self.median_s * 1e3,
            self.p10_s * 1e3,
            self.p90_s * 1e3,
            self.reps
        )
    }
}

/// Time `f` with `warmup` unmeasured runs then `reps` measured runs.
pub fn time_fn<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Timing {
    assert!(reps >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / reps as f64;
    Timing {
        reps,
        median_s: crate::util::stats::percentile(&samples, 50.0),
        p10_s: crate::util::stats::percentile(&samples, 10.0),
        p90_s: crate::util::stats::percentile(&samples, 90.0),
        mean_s: mean,
    }
}

/// Auto-calibrating variant: picks reps so the measured block runs for
/// roughly `budget_s` seconds total (at least `min_reps`).
pub fn time_auto<F: FnMut()>(budget_s: f64, min_reps: usize, mut f: F) -> Timing {
    let t0 = Instant::now();
    f(); // warmup + probe
    let probe = t0.elapsed().as_secs_f64().max(1e-9);
    let reps = ((budget_s / probe) as usize).clamp(min_reps, 10_000);
    time_fn(0, reps, f)
}

/// Simple fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    /// Print the aligned table to stdout.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }

    /// Also dump as CSV under bench_results/.
    pub fn write_csv(&self, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("bench_results")?;
        let path = format!("bench_results/{name}.csv");
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        eprintln!("wrote {path}");
        Ok(())
    }
}

/// Write a machine-readable perf artifact under `bench_results/` —
/// `BENCH_<name>.json`, the convention CI uploads as a workflow
/// artifact (EXPERIMENTS.md §Perf). Returns the written path.
pub fn write_json_artifact(
    name: &str,
    value: &crate::util::JsonValue,
) -> std::io::Result<String> {
    std::fs::create_dir_all("bench_results")?;
    let path = format!("bench_results/BENCH_{name}.json");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{}", value.to_string_pretty())?;
    eprintln!("wrote {path}");
    Ok(path)
}

/// The shared work-stealing fan-out (see [`crate::util::parallel`]),
/// re-exported here because the fig1/fig2 bench grids are its original
/// public surface.
pub use crate::util::parallel::run_cells;

/// Wall-clock a closure: `(result, seconds)`.
pub fn wall_time<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Serial-over-parallel wall-clock ratio (> 1 means the parallel run
/// won); reported in the EXPERIMENTS.md §Perf speedup table.
pub fn speedup(serial_s: f64, parallel_s: f64) -> f64 {
    if parallel_s > 0.0 {
        serial_s / parallel_s
    } else {
        f64::INFINITY
    }
}

/// The `--threads N` bench argument — how fig1/fig2 and the examples
/// pick up the parallel runtime without a config file. `0` (and an
/// absent flag) means "auto", deferring to the `RUN_THREADS` env var
/// and finally serial — the same semantics as `run.threads`.
pub fn threads_arg() -> usize {
    let args = bench_args();
    let mut explicit: Option<usize> = None;
    for (i, a) in args.iter().enumerate() {
        if let Some(v) = a.strip_prefix("--threads=") {
            explicit = v.parse::<usize>().ok();
        } else if a == "--threads" {
            explicit = args.get(i + 1).and_then(|v| v.parse::<usize>().ok());
        }
    }
    match explicit {
        Some(n) if n >= 1 => n,
        _ => std::env::var("RUN_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1),
    }
}

/// `cargo bench` passes `--bench`; strip the harness-reserved args so
/// benches can read their own (e.g. `--quick`).
pub fn bench_args() -> Vec<String> {
    std::env::args()
        .skip(1)
        .filter(|a| a != "--bench" && !a.starts_with("--save-baseline"))
        .collect()
}

/// True when the bench should run in a reduced "smoke" configuration
/// (ADLOCO_BENCH_QUICK=1 or --quick).
pub fn quick_mode() -> bool {
    std::env::var("ADLOCO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
        || bench_args().iter().any(|a| a == "--quick")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures() {
        let t = time_fn(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.reps, 5);
        assert!(t.median_s >= 0.0);
        assert!(t.p10_s <= t.p90_s);
    }

    #[test]
    fn speedup_and_wall_time() {
        assert!((speedup(2.0, 1.0) - 2.0).abs() < 1e-12);
        assert!(speedup(1.0, 0.0).is_infinite());
        let (v, secs) = wall_time(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
        // csv write goes to bench_results/ in cwd; use temp cwd-safe name
        t.write_csv("benchkit_selftest").unwrap();
        let text = std::fs::read_to_string("bench_results/benchkit_selftest.csv").unwrap();
        assert!(text.contains("a,b"));
        std::fs::remove_file("bench_results/benchkit_selftest.csv").ok();
    }

    #[test]
    fn json_artifact_roundtrips() {
        use crate::util::JsonValue;
        let v = JsonValue::obj(vec![
            ("bench", JsonValue::str("selftest")),
            ("rows", JsonValue::Array(vec![JsonValue::num(1.0), JsonValue::num(2.0)])),
        ]);
        let path = write_json_artifact("selftest", &v).unwrap();
        assert_eq!(path, "bench_results/BENCH_selftest.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let back = JsonValue::parse(text.trim()).unwrap();
        assert_eq!(back.get("bench").and_then(|x| x.as_str()), Some("selftest"));
        std::fs::remove_file(&path).ok();
    }
}
