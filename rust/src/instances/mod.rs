//! The elastic trainer-lifecycle layer (DESIGN.md §9): stable instance
//! identities, the Spawn → Active → Merging → Retired state machine,
//! and the utilization-driven spawn controller that turns the paper's
//! "multiple lightweight training streams" into a *runtime* quantity.
//!
//! Before this layer the instance pool was frozen at config time
//! (`algo.num_trainers × workers_per_trainer`): MIT merges only ever
//! shrank it, and capacity freed by churn or merges sat idle for the
//! rest of the run. The registry decouples **who an instance is** (its
//! [`InstanceId`], stable for the whole run and never re-indexed) from
//! **where it computes** (clock slots and node assignments, allocated
//! dynamically by the cluster layer), so the coordinator can grow the
//! pool mid-run without disturbing any existing stream.
//!
//! Two design rules keep the elastic layer inside the determinism
//! contract (DESIGN.md §6):
//!
//! * the spawn decision ([`plan_spawns`]) is a **pure function** of the
//!   accumulated per-node utilization statistics — themselves contract
//!   fields — so lockstep, event and any thread count agree on every
//!   spawn;
//! * a spawned instance's stochastic streams are seeded from
//!   `derive_seed(cfg.seed, "instance=<id>")`, never drawn from the
//!   coordinator's main stream, so `elastic = off` runs replay every
//!   historical draw sequence bit-for-bit.

use crate::config::ElasticMode;

/// Stable identity of one training instance. Equal to the instance's
/// position in the coordinator's (append-only) trainer pool: seed
/// instances occupy `0..num_trainers`, spawned instances append after
/// them, and no id is ever reused or re-indexed — unlike clock slots,
/// which are a placement concern the cluster layer owns.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct InstanceId(
    /// Position in the coordinator's append-only trainer pool.
    pub usize,
);

impl std::fmt::Display for InstanceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "instance={}", self.0)
    }
}

/// Lifecycle states of an instance (DESIGN.md §9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LifecycleState {
    /// Created this round; becomes [`LifecycleState::Active`] after its
    /// first completed outer round.
    Spawned,
    /// Participating in inner loops, syncs and merge selection.
    Active,
    /// Selected by CheckMerge this round. Transient and **call-internal
    /// only**: `mark_merging` and `resolve_merge` run within a single
    /// merge round, so the state resolves to `Active` (representative)
    /// or `Retired` (consumed) before any snapshot, census or
    /// `registry()` read can observe it — it exists so the state
    /// machine names the selection step, not as a serialized state.
    Merging,
    /// Consumed by a merge; takes no further part. Its frozen clock
    /// slots accrue [`crate::metrics::UtilRecord::vacant_s`].
    Retired,
}

impl LifecycleState {
    /// Canonical lowercase name (checkpoint header encoding).
    pub fn as_str(self) -> &'static str {
        match self {
            LifecycleState::Spawned => "spawned",
            LifecycleState::Active => "active",
            LifecycleState::Merging => "merging",
            LifecycleState::Retired => "retired",
        }
    }

    /// Parse a checkpoint-header state name.
    pub fn parse(s: &str) -> Option<LifecycleState> {
        match s {
            "spawned" => Some(LifecycleState::Spawned),
            "active" => Some(LifecycleState::Active),
            "merging" => Some(LifecycleState::Merging),
            "retired" => Some(LifecycleState::Retired),
            _ => None,
        }
    }
}

/// How an instance came to exist.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Origin {
    /// Part of the initial `algo.num_trainers` pool.
    Seed,
    /// Spawned by the utilization controller on an underused node.
    UtilSpawn,
    /// Respawned after a merge retired part of the pool
    /// (`algo.elastic = respawn_after_merge`).
    MergeRespawn,
}

impl Origin {
    /// Canonical lowercase name (checkpoint header encoding).
    pub fn as_str(self) -> &'static str {
        match self {
            Origin::Seed => "seed",
            Origin::UtilSpawn => "util",
            Origin::MergeRespawn => "respawn",
        }
    }

    /// Parse a checkpoint-header origin name.
    pub fn parse(s: &str) -> Option<Origin> {
        match s {
            "seed" => Some(Origin::Seed),
            "util" => Some(Origin::UtilSpawn),
            "respawn" => Some(Origin::MergeRespawn),
            _ => None,
        }
    }
}

/// Lifecycle metadata of one instance (the registry row).
#[derive(Clone, Debug)]
pub struct InstanceMeta {
    /// Stable identity (== position in the trainer pool).
    pub id: InstanceId,
    /// Current lifecycle state.
    pub state: LifecycleState,
    /// Outer step the instance joined the pool (0 for seed instances).
    pub born_outer: u64,
    /// Virtual time the instance joined (0.0 for seed instances) — the
    /// moment its workers started re-occupying node capacity, which is
    /// when the vacancy accounting stops charging the capacity it
    /// reclaimed (DESIGN.md §9).
    pub born_at_s: f64,
    /// Outer step a merge retired it, if any.
    pub retired_outer: Option<u64>,
    /// How it came to exist.
    pub origin: Origin,
}

/// The elastic instance registry: one append-only row per instance that
/// ever existed, plus the spawn controller's persistent state. The
/// coordinator owns one; the trainer pool's `alive` flags stay the
/// numeric source of truth while the registry carries the lifecycle
/// view (states, birth/retirement rounds, spawn bookkeeping).
#[derive(Clone, Debug)]
pub struct InstanceRegistry {
    metas: Vec<InstanceMeta>,
    /// Per-node worker-slot capacity the spawn controller respects.
    pub node_capacity: Vec<usize>,
    /// Instances spawned over the run so far.
    pub spawn_count: u64,
    /// Outer step of the most recent spawn round (0 = never) — the
    /// controller's cooldown anchor.
    pub last_spawn_outer: u64,
    /// Representative of the most recent merge, if any: the "last merge
    /// product" new instances seed their parameters from.
    pub last_merge_rep: Option<usize>,
}

impl InstanceRegistry {
    /// Registry over the initial pool of `k` seed instances with the
    /// given per-node capacities.
    pub fn seed(k: usize, node_capacity: Vec<usize>) -> InstanceRegistry {
        InstanceRegistry {
            metas: (0..k)
                .map(|i| InstanceMeta {
                    id: InstanceId(i),
                    state: LifecycleState::Active,
                    born_outer: 0,
                    born_at_s: 0.0,
                    retired_outer: None,
                    origin: Origin::Seed,
                })
                .collect(),
            node_capacity,
            spawn_count: 0,
            last_spawn_outer: 0,
            last_merge_rep: None,
        }
    }

    /// Every registry row, in id order.
    pub fn metas(&self) -> &[InstanceMeta] {
        &self.metas
    }

    /// Total instances that ever existed (seed + spawned).
    pub fn len(&self) -> usize {
        self.metas.len()
    }

    /// True when no instance was ever registered.
    pub fn is_empty(&self) -> bool {
        self.metas.is_empty()
    }

    /// One row by id.
    pub fn meta(&self, id: usize) -> &InstanceMeta {
        &self.metas[id]
    }

    /// Instances currently in the pool (anything not retired).
    pub fn live_count(&self) -> usize {
        self.metas.iter().filter(|m| m.state != LifecycleState::Retired).count()
    }

    /// Append a freshly spawned instance; returns its stable id.
    pub fn register_spawn(
        &mut self,
        born_outer: u64,
        born_at_s: f64,
        origin: Origin,
    ) -> InstanceId {
        let id = InstanceId(self.metas.len());
        self.metas.push(InstanceMeta {
            id,
            state: LifecycleState::Spawned,
            born_outer,
            born_at_s,
            retired_outer: None,
            origin,
        });
        self.spawn_count += 1;
        self.last_spawn_outer = born_outer;
        id
    }

    /// Promote round-old `Spawned` rows to `Active` (called at each
    /// outer boundary after the inner phase completed).
    pub fn activate_spawned(&mut self) {
        for m in &mut self.metas {
            if m.state == LifecycleState::Spawned {
                m.state = LifecycleState::Active;
            }
        }
    }

    /// Mark a CheckMerge selection (transient `Merging` state).
    pub fn mark_merging(&mut self, ids: &[usize]) {
        for &id in ids {
            if self.metas[id].state != LifecycleState::Retired {
                self.metas[id].state = LifecycleState::Merging;
            }
        }
    }

    /// Resolve a merge: the representative returns to `Active`, the
    /// consumed instances retire at `outer_step`.
    pub fn resolve_merge(&mut self, representative: usize, removed: &[usize], outer_step: u64) {
        self.metas[representative].state = LifecycleState::Active;
        for &id in removed {
            self.metas[id].state = LifecycleState::Retired;
            self.metas[id].retired_outer = Some(outer_step);
        }
        self.last_merge_rep = Some(representative);
    }

    /// Restore one row from a checkpoint (rows arrive in id order; the
    /// registry must have been freshly seeded for the config first).
    /// A gap in the id sequence is a damaged or hand-edited checkpoint
    /// — reported as an error, never a panic, so the crash-fault
    /// harness's corrupted files always fail cleanly.
    pub fn restore_row(&mut self, row: InstanceMeta) -> anyhow::Result<()> {
        let id = row.id.0;
        if id < self.metas.len() {
            self.metas[id] = row;
        } else {
            anyhow::ensure!(
                id == self.metas.len(),
                "registry rows must restore in id order (got id {id} with {} rows)",
                self.metas.len()
            );
            self.metas.push(row);
        }
        Ok(())
    }
}

/// One node's load summary the spawn controller decides over — built by
/// the coordinator from the accumulated per-slot utilization accounting
/// (all determinism-contract fields, so every scheduler/thread count
/// sees identical loads).
#[derive(Clone, Copy, Debug)]
pub struct NodeLoad {
    /// Node id.
    pub node: usize,
    /// Worker-slot capacity of the node.
    pub capacity: usize,
    /// Worker slots currently owned by live instances.
    pub assigned: usize,
    /// Idle fraction of the node's assigned workers so far:
    /// `(wait + preempted) / (busy + wait + comm + preempted)`, or 1.0
    /// for a node with capacity but no assigned live instance (churn-
    /// or merge-freed capacity).
    pub idle_frac: f64,
    /// False while the node is preempted by the churn scenario —
    /// spawning onto a down node is never useful.
    pub available: bool,
}

/// The controller's instance budget and pacing inputs (bundled so
/// [`plan_spawns`] stays a readable pure function).
#[derive(Clone, Copy, Debug)]
pub struct SpawnBudget {
    /// Instances live right now.
    pub live_instances: usize,
    /// Hard cap on live instances.
    pub max_instances: usize,
    /// False while the `util_threshold` cooldown has not elapsed.
    pub cooldown_ok: bool,
    /// Instances retired by this round's merge (the respawn budget).
    pub merge_freed: usize,
    /// Worker slots **each spawned instance occupies**
    /// (`elastic.workers_per_spawn`) — capacity checks are in slots,
    /// so a wide spawn needs that much room on its node.
    pub spawn_width: usize,
}

/// The spawn controller (DESIGN.md §9): decide which nodes receive a new
/// lightweight instance this round. A **pure function** of its inputs:
///
/// * `Off` (or a cooldown that has not elapsed in `util_threshold`
///   mode) ⇒ no spawns, unconditionally;
/// * `UtilThreshold` ⇒ at most one spawn per node per round, on every
///   available node with room for a full `spawn_width`-slot instance
///   whose `idle_frac` reaches `idle_threshold`, in ascending node
///   order, until the instance budget
///   (`max_instances − live_instances`) runs out;
/// * `RespawnAfterMerge` ⇒ up to `merge_freed` spawns (the instances
///   the round's merge retired), placed on the least-loaded available
///   nodes with room (ties broken by node id), also bounded by the
///   instance budget.
///
/// Guarantees (property-tested in `tests/properties.rs`): the returned
/// placement never exceeds any node's slot capacity — counting
/// `spawn_width` slots per placement — never pushes the live count
/// past `max_instances`, and — for `UtilThreshold` — a node's
/// eligibility is monotone in its idle fraction.
pub fn plan_spawns(
    mode: ElasticMode,
    idle_threshold: f64,
    loads: &[NodeLoad],
    budget: &SpawnBudget,
) -> Vec<usize> {
    let width = budget.spawn_width.max(1);
    let instances = budget.max_instances.saturating_sub(budget.live_instances);
    if instances == 0 {
        return Vec::new();
    }
    match mode {
        ElasticMode::Off => Vec::new(),
        ElasticMode::UtilThreshold => {
            if !budget.cooldown_ok {
                return Vec::new();
            }
            loads
                .iter()
                .filter(|l| l.available && l.assigned + width <= l.capacity)
                .filter(|l| l.idle_frac >= idle_threshold)
                .map(|l| l.node)
                .take(instances)
                .collect()
        }
        ElasticMode::RespawnAfterMerge => {
            let want = budget.merge_freed.min(instances);
            if want == 0 {
                return Vec::new();
            }
            // least-loaded first, ties by node id; a node may take
            // several respawns as long as its slot capacity allows
            let mut free: Vec<(usize, usize, usize)> = loads
                .iter()
                .filter(|l| l.available && l.assigned + width <= l.capacity)
                .map(|l| (l.assigned, l.node, l.capacity))
                .collect();
            let mut out = Vec::with_capacity(want);
            while out.len() < want {
                let Some(slot) = free
                    .iter_mut()
                    .filter(|s| s.0 + width <= s.2)
                    .min_by_key(|s| (s.0, s.1))
                else {
                    break;
                };
                out.push(slot.1);
                slot.0 += width;
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(node: usize, capacity: usize, assigned: usize, idle: f64) -> NodeLoad {
        NodeLoad { node, capacity, assigned, idle_frac: idle, available: true }
    }

    fn budget(live: usize, max: usize, cooldown_ok: bool, freed: usize) -> SpawnBudget {
        SpawnBudget {
            live_instances: live,
            max_instances: max,
            cooldown_ok,
            merge_freed: freed,
            spawn_width: 1,
        }
    }

    #[test]
    fn registry_lifecycle_walk() {
        let mut reg = InstanceRegistry::seed(2, vec![1, 1]);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.live_count(), 2);
        assert_eq!(reg.meta(0).origin, Origin::Seed);
        let id = reg.register_spawn(3, 12.5, Origin::UtilSpawn);
        assert_eq!(id, InstanceId(2));
        assert_eq!(reg.meta(2).state, LifecycleState::Spawned);
        assert_eq!(reg.meta(2).born_at_s, 12.5);
        assert_eq!(reg.spawn_count, 1);
        assert_eq!(reg.last_spawn_outer, 3);
        reg.activate_spawned();
        assert_eq!(reg.meta(2).state, LifecycleState::Active);
        reg.mark_merging(&[0, 1]);
        assert_eq!(reg.meta(0).state, LifecycleState::Merging);
        reg.resolve_merge(0, &[1], 4);
        assert_eq!(reg.meta(0).state, LifecycleState::Active);
        assert_eq!(reg.meta(1).state, LifecycleState::Retired);
        assert_eq!(reg.meta(1).retired_outer, Some(4));
        assert_eq!(reg.last_merge_rep, Some(0));
        assert_eq!(reg.live_count(), 2, "spawn replaced the retired instance");
    }

    #[test]
    fn state_and_origin_names_roundtrip() {
        for s in [
            LifecycleState::Spawned,
            LifecycleState::Active,
            LifecycleState::Merging,
            LifecycleState::Retired,
        ] {
            assert_eq!(LifecycleState::parse(s.as_str()), Some(s));
        }
        for o in [Origin::Seed, Origin::UtilSpawn, Origin::MergeRespawn] {
            assert_eq!(Origin::parse(o.as_str()), Some(o));
        }
        assert!(LifecycleState::parse("gone").is_none());
        assert!(Origin::parse("nowhere").is_none());
        assert_eq!(InstanceId(7).to_string(), "instance=7");
    }

    #[test]
    fn off_mode_never_spawns() {
        let loads = vec![load(0, 4, 0, 1.0), load(1, 4, 0, 1.0)];
        let s = plan_spawns(ElasticMode::Off, 0.0, &loads, &budget(1, 100, true, 5));
        assert!(s.is_empty());
    }

    #[test]
    fn util_threshold_picks_idle_nodes_with_free_capacity() {
        let loads = vec![
            load(0, 2, 2, 0.9), // idle but full
            load(1, 2, 1, 0.5), // idle with room -> spawn
            load(2, 2, 1, 0.1), // busy -> skip
            load(3, 2, 0, 1.0), // freed capacity -> spawn
        ];
        let s = plan_spawns(ElasticMode::UtilThreshold, 0.3, &loads, &budget(4, 8, true, 0));
        assert_eq!(s, vec![1, 3]);
        // cooldown gates everything
        let s = plan_spawns(ElasticMode::UtilThreshold, 0.3, &loads, &budget(4, 8, false, 0));
        assert!(s.is_empty());
        // budget truncates in ascending node order
        let s = plan_spawns(ElasticMode::UtilThreshold, 0.3, &loads, &budget(7, 8, true, 0));
        assert_eq!(s, vec![1]);
    }

    #[test]
    fn util_threshold_skips_unavailable_nodes() {
        let mut down = load(0, 2, 0, 1.0);
        down.available = false;
        let loads = vec![down, load(1, 2, 0, 1.0)];
        let s = plan_spawns(ElasticMode::UtilThreshold, 0.5, &loads, &budget(2, 8, true, 0));
        assert_eq!(s, vec![1], "preempted node must not receive a spawn");
    }

    #[test]
    fn wide_spawns_need_room_for_every_worker_slot() {
        // spawn_width = 2: a node with 1 free slot is NOT eligible
        let loads = vec![load(0, 2, 1, 1.0), load(1, 3, 1, 1.0), load(2, 4, 0, 0.0)];
        let wide = SpawnBudget { spawn_width: 2, ..budget(0, 16, true, 4) };
        let s = plan_spawns(ElasticMode::UtilThreshold, 0.5, &loads, &wide);
        assert_eq!(s, vec![1], "only node 1 has 2 free slots above threshold");
        // respawn accounting charges the full width per placement —
        // least-loaded first: node 2 (0/4), then node 1 (1/3), then
        // node 2 again (2/4); node 1 is then full for a 2-wide spawn
        let s = plan_spawns(ElasticMode::RespawnAfterMerge, 0.5, &loads, &wide);
        assert_eq!(s, vec![2, 1, 2]);
    }

    #[test]
    fn respawn_fills_least_loaded_first() {
        let loads = vec![load(0, 2, 2, 0.0), load(1, 2, 1, 0.0), load(2, 2, 0, 0.0)];
        let s = plan_spawns(ElasticMode::RespawnAfterMerge, 0.9, &loads, &budget(3, 8, true, 3));
        // node 2 (0 assigned) first, then node 1 and node 2 tie at 1 ->
        // node 1 by id, then node 2 again
        assert_eq!(s, vec![2, 1, 2]);
        // capacity exhausts the fill even when more were freed
        let s =
            plan_spawns(ElasticMode::RespawnAfterMerge, 0.9, &loads, &budget(3, 16, true, 10));
        assert_eq!(s.len(), 3, "only 3 free slots exist");
        // budget binds before freed count
        let s = plan_spawns(ElasticMode::RespawnAfterMerge, 0.9, &loads, &budget(7, 8, true, 3));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn spawns_never_exceed_capacity_or_budget() {
        let loads = vec![load(0, 1, 0, 1.0), load(1, 3, 2, 1.0)];
        for mode in [ElasticMode::UtilThreshold, ElasticMode::RespawnAfterMerge] {
            let s = plan_spawns(mode, 0.0, &loads, &budget(0, 100, true, 100));
            for &n in &loads {
                let placed = s.iter().filter(|&&x| x == n.node).count();
                assert!(
                    n.assigned + placed <= n.capacity,
                    "{mode:?}: node {} over capacity",
                    n.node
                );
            }
        }
        let s = plan_spawns(ElasticMode::UtilThreshold, 0.0, &loads, &budget(99, 100, true, 0));
        assert!(s.len() <= 1, "budget of 1 must bound the plan");
    }
}
