//! Adaptive batch sizing (paper §3.3, §4.2): the norm test, the
//! inner-product test, the augmented test, EMA smoothing of the noisy
//! variance statistics, rounding onto the AOT batch-size ladder, and the
//! SwitchMode gradient-accumulation policy.
//!
//! The controller is deliberately pure/deterministic: `observe()` folds in
//! the statistics of the step that just ran, `requested()` returns the
//! b_req the trainer stores for the next outer step (Algorithm 3 line 31),
//! and `plan()` maps a request onto (micro_batch, accum_steps) given the
//! hardware max_batch (Algorithm 3 lines 17-27).

use crate::config::{BatchTest, BatchingConfig};
use crate::engine::StepStats;
use crate::util::stats::Ema;

/// Execution plan for one inner step at a requested batch size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepPlan {
    /// Batch each engine call runs at (a ladder rung <= max_batch).
    pub micro_batch: usize,
    /// Number of accumulated micro-steps (1 = plain step).
    pub accum_steps: usize,
    /// True when SwitchMode engaged (b_req > n * max_batch).
    pub switched: bool,
    /// True when [`round_to_ladder`] saturated below the hardware
    /// budget: the AOT ladder's top rung is smaller than
    /// `min(b_req, max_batch)`, so the plan runs a smaller micro batch
    /// than the hardware (and Algorithm 3) intended. The flag is
    /// surfaced per step in the recorder (`StepRecord.clamped`) instead
    /// of capping silently; the arithmetic itself is unchanged so
    /// existing runs stay bit-identical. Note the deliberate SwitchMode
    /// dead zone (`max_batch < b_req <= n·max_batch`, clamped to
    /// `max_batch` to keep full update frequency — paper §4.2) is NOT a
    /// clamp: it is the intended plan.
    pub clamped: bool,
}

impl StepPlan {
    /// Total samples consumed by the plan.
    pub fn effective_batch(&self) -> usize {
        self.micro_batch * self.accum_steps
    }
}

/// Round a requested batch up to the smallest supported ladder rung;
/// saturates at the top rung. `ladder` must be ascending and non-empty.
pub fn round_to_ladder(b: usize, ladder: &[usize]) -> usize {
    debug_assert!(!ladder.is_empty());
    for &rung in ladder {
        if rung >= b {
            return rung;
        }
    }
    *ladder.last().unwrap()
}

/// SwitchMode policy (paper §4.2 + Algorithm 3 lines 17-27):
/// accumulation engages only once b_req *strictly exceeds*
/// `multiplier * max_batch` (paper: n = 2); below that the batch is
/// clamped to max_batch and full update frequency is kept.
///
/// Boundary semantics, pinned (Algorithm 3's test is the real-valued
/// `b_req > n·max_batch`): at `b_req == floor(n·max_batch)` exactly the
/// plan does NOT switch — equality is "still affordable at full update
/// frequency". Because `b_req` is an integer, `b_req > n·max_batch`
/// over the reals and `b_req > floor(n·max_batch)` over the integers
/// select the same set, so the floored threshold is not an off-by-one:
/// the first switching request is `floor(n·max_batch) + 1` for every
/// multiplier, integer or fractional (`switch_mode_threshold_boundary`
/// pins both sides of the rung).
///
/// Ladder saturation never changes the arithmetic — it raises the
/// plan's [`StepPlan::clamped`] flag instead, which the coordinator
/// surfaces per step in the run records.
pub fn plan_step(
    b_req: usize,
    max_batch: usize,
    multiplier: f64,
    switch_enabled: bool,
    ladder: &[usize],
) -> StepPlan {
    debug_assert!(max_batch >= 1);
    let b_req = b_req.max(1);
    let threshold = (multiplier * max_batch as f64).floor() as usize;
    if switch_enabled && b_req > threshold {
        // accumulate ceil(b_req / max_batch) micro-steps of max_batch
        let micro = round_to_ladder(max_batch, ladder).min(max_batch);
        let accum = b_req.div_ceil(max_batch);
        let clamped = micro < b_req.min(max_batch);
        StepPlan { micro_batch: micro, accum_steps: accum, switched: true, clamped }
    } else {
        let want = b_req.min(max_batch);
        let micro = round_to_ladder(want, ladder).min(max_batch).max(1);
        let clamped = micro < want;
        StepPlan { micro_batch: micro, accum_steps: 1, switched: false, clamped }
    }
}

/// The controller's full statistical state — what a checkpoint must
/// capture for the resumed request sequence to continue bit-for-bit
/// (config-derived knobs like `ema_beta` are rebuilt from the config).
#[derive(Clone, Debug, PartialEq)]
pub struct ControllerState {
    /// Current requested batch b_req.
    pub requested: usize,
    /// Step statistics folded in so far.
    pub observations: u64,
    /// `(value, steps)` of the sigma² EMA.
    pub sigma2_ema: (f64, u64),
    /// `(value, steps)` of the inner-product-variance EMA.
    pub ip_var_ema: (f64, u64),
    /// `(value, steps)` of the gradient-norm EMA.
    pub s1_ema: (f64, u64),
}

/// Per-trainer adaptive batch controller.
#[derive(Clone, Debug)]
pub struct BatchController {
    cfg: BatchingConfig,
    requested: usize,
    sigma2_ema: Ema,
    ip_var_ema: Ema,
    s1_ema: Ema,
    observations: u64,
}

impl BatchController {
    /// Controller starting at `cfg.initial_batch` with empty statistics.
    pub fn new(cfg: BatchingConfig) -> Self {
        let beta = if cfg.ema_beta > 0.0 { cfg.ema_beta } else { 0.0 };
        BatchController {
            requested: cfg.initial_batch,
            sigma2_ema: Ema::new(beta),
            ip_var_ema: Ema::new(beta),
            s1_ema: Ema::new(beta),
            cfg,
        observations: 0,
        }
    }

    /// Current requested batch b_req (Algorithm 3 stores this per trainer).
    pub fn requested(&self) -> usize {
        self.requested
    }

    /// Force a request (used by DoMerge when the representative inherits
    /// the merged trainers' state, and by tests).
    pub fn set_requested(&mut self, b: usize) {
        self.requested = b.max(1);
    }

    /// Number of step statistics folded in so far.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Capture the controller's statistical state for a checkpoint.
    pub fn export_state(&self) -> ControllerState {
        ControllerState {
            requested: self.requested,
            observations: self.observations,
            sigma2_ema: self.sigma2_ema.state(),
            ip_var_ema: self.ip_var_ema.state(),
            s1_ema: self.s1_ema.state(),
        }
    }

    /// Restore a captured [`ControllerState`] (checkpoint resume): the
    /// next `observe` continues the exact request sequence of the saved
    /// run.
    pub fn restore_state(&mut self, st: &ControllerState) {
        self.requested = st.requested.max(1);
        self.observations = st.observations;
        self.sigma2_ema.set_state(st.sigma2_ema.0, st.sigma2_ema.1);
        self.ip_var_ema.set_state(st.ip_var_ema.0, st.ip_var_ema.1);
        self.s1_ema.set_state(st.s1_ema.0, st.s1_ema.1);
    }

    /// Fold in the statistics of a completed gradient computation (which
    /// ran at `executed_batch` effective samples) and update the
    /// requested batch size.
    ///
    /// `stats.sigma2 == 0` (single-chunk batches can't estimate variance)
    /// falls back to the EMA history; with no history at all the request
    /// becomes 2x the *executed* batch — a geometric probe that mirrors
    /// how AdAdaGrad implementations warm up from batch 1 without
    /// compounding across the many inner steps that share one plan
    /// (Algorithm 3 recomputes b_req once per outer step).
    pub fn observe(&mut self, stats: &StepStats, executed_batch: usize) {
        if !self.cfg.adaptive {
            return;
        }
        self.observations += 1;
        if stats.sigma2 > 0.0 {
            self.sigma2_ema.push(stats.sigma2);
        }
        if stats.ip_var > 0.0 {
            self.ip_var_ema.push(stats.ip_var);
        }
        if stats.grad_sq_norm > 0.0 {
            self.s1_ema.push(stats.grad_sq_norm);
        }

        let s1 = self.smoothed(&self.s1_ema, stats.grad_sq_norm);
        let new_req = match self.cfg.test {
            BatchTest::Norm => self.norm_test(s1, stats),
            BatchTest::InnerProduct => self.inner_product_test(s1, stats),
            BatchTest::Augmented => self.augmented_test(s1, stats),
        };
        let new_req = match new_req {
            Some(b) => b,
            // no usable statistic yet: geometric warm-up probe anchored
            // at the batch that actually ran
            None => executed_batch.max(1).saturating_mul(2),
        };
        let mut req = if self.cfg.monotone {
            self.requested.max(new_req).max(1)
        } else {
            new_req.max(1)
        };
        if self.cfg.max_request > 0 {
            req = req.min(self.cfg.max_request);
        }
        self.requested = req;
    }

    fn smoothed(&self, ema: &Ema, instant: f64) -> f64 {
        if self.cfg.ema_beta > 0.0 {
            ema.get().unwrap_or(instant)
        } else {
            instant
        }
    }

    /// Norm test, Eq. 10: b = ceil(sigma^2 / (eta^2 ||gbar||^2)).
    fn norm_test(&self, s1: f64, stats: &StepStats) -> Option<usize> {
        let sigma2 = if stats.sigma2 > 0.0 {
            self.smoothed(&self.sigma2_ema, stats.sigma2)
        } else {
            self.sigma2_ema.get()?
        };
        if s1 <= 0.0 {
            return None;
        }
        Some(ceil_div_f64(sigma2, self.cfg.eta * self.cfg.eta * s1))
    }

    /// Inner-product test, Eq. 12:
    /// b = ceil(Var_i(<g_i, gbar>) / (theta^2 ||gbar||^4)).
    fn inner_product_test(&self, s1: f64, stats: &StepStats) -> Option<usize> {
        let ip_var = if stats.ip_var > 0.0 {
            self.smoothed(&self.ip_var_ema, stats.ip_var)
        } else {
            self.ip_var_ema.get()?
        };
        if s1 <= 0.0 {
            return None;
        }
        Some(ceil_div_f64(ip_var, self.cfg.theta * self.cfg.theta * s1 * s1))
    }

    /// Augmented inner-product test, Eq. 13: max of the inner-product
    /// request and the orthogonal-residual term
    /// Var_i(g_i - proj_gbar(g_i)) / (nu^2 ||gbar||^2).
    ///
    /// The orthogonal variance decomposes as
    /// sigma^2_total - Var_i(<g_i, ghat>) = sigma2 - ip_var / ||gbar||^2,
    /// so it is computable from the same two fused statistics the Pallas
    /// kernel already produces (paper §3.3.2 notes the two terms differ by
    /// ~1e7 in practice — the IPT bench reproduces that observation).
    fn augmented_test(&self, s1: f64, stats: &StepStats) -> Option<usize> {
        let base = self.inner_product_test(s1, stats)?;
        let sigma2 = if stats.sigma2 > 0.0 {
            self.smoothed(&self.sigma2_ema, stats.sigma2)
        } else {
            self.sigma2_ema.get()?
        };
        let ip_var = if stats.ip_var > 0.0 {
            self.smoothed(&self.ip_var_ema, stats.ip_var)
        } else {
            self.ip_var_ema.get()?
        };
        if s1 <= 0.0 {
            return None;
        }
        let orth_var = (sigma2 - ip_var / s1).max(0.0);
        let aug = ceil_div_f64(orth_var, self.cfg.nu * self.cfg.nu * s1);
        Some(base.max(aug))
    }
}

fn ceil_div_f64(num: f64, den: f64) -> usize {
    if den <= 0.0 || !num.is_finite() {
        return usize::MAX / 4; // effectively "as large as possible"
    }
    let v = (num / den).ceil();
    if v < 1.0 {
        1
    } else if v > 1e12 {
        usize::MAX / 4
    } else {
        v as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn cfg() -> BatchingConfig {
        let mut c = presets::paper_table1().algo.batching;
        c.ema_beta = 0.0; // raw statistics for exact arithmetic checks
        c
    }

    fn stats(loss: f64, s1: f64, sigma2: f64, ip_var: f64) -> StepStats {
        StepStats { loss, grad_sq_norm: s1, sigma2, ip_var }
    }

    #[test]
    fn ladder_rounding() {
        let ladder = [1, 2, 4, 8, 16];
        assert_eq!(round_to_ladder(1, &ladder), 1);
        assert_eq!(round_to_ladder(3, &ladder), 4);
        assert_eq!(round_to_ladder(16, &ladder), 16);
        assert_eq!(round_to_ladder(100, &ladder), 16);
    }

    #[test]
    fn norm_test_matches_eq10() {
        // mirrors python tests: sigma2 = 8, eta=0.8, s1=2 -> ceil(8/1.28)=7
        let mut c = BatchController::new(cfg());
        c.observe(&stats(1.0, 2.0, 8.0, 0.0), 4);
        assert_eq!(c.requested(), 7);
    }

    #[test]
    fn inner_product_test_matches_eq12() {
        let mut bc = cfg();
        bc.test = BatchTest::InnerProduct;
        bc.theta = 0.5;
        let mut c = BatchController::new(bc);
        // ip_var = 20/3, s1 = 2 -> ceil((20/3) / (0.25 * 4)) = 7
        c.observe(&stats(1.0, 2.0, 0.0, 20.0 / 3.0), 4);
        assert_eq!(c.requested(), 7);
    }

    #[test]
    fn augmented_takes_max() {
        let mut bc = cfg();
        bc.test = BatchTest::Augmented;
        bc.theta = 0.5;
        bc.nu = 0.1;
        let mut c = BatchController::new(bc);
        // ip request: ceil((20/3)/(0.25*4)) = 7
        // orth_var = sigma2 - ip_var/s1 = 10 - (20/3)/2 = 6.667
        // aug: ceil(6.667 / (0.01 * 2)) = 334 -> max = 334
        c.observe(&stats(1.0, 2.0, 10.0, 20.0 / 3.0), 4);
        assert_eq!(c.requested(), 334);
    }

    #[test]
    fn monotone_growth() {
        let mut c = BatchController::new(cfg());
        c.observe(&stats(1.0, 1.0, 10.0, 0.0), 4); // req = ceil(10/0.64) = 16
        assert_eq!(c.requested(), 16);
        c.observe(&stats(1.0, 100.0, 1.0, 0.0), 4); // raw request tiny
        assert_eq!(c.requested(), 16, "monotone controller must not shrink");
    }

    #[test]
    fn non_monotone_can_shrink() {
        let mut bc = cfg();
        bc.monotone = false;
        let mut c = BatchController::new(bc);
        c.observe(&stats(1.0, 1.0, 10.0, 0.0), 4);
        assert_eq!(c.requested(), 16);
        c.observe(&stats(1.0, 100.0, 1.0, 0.0), 4);
        assert!(c.requested() < 16);
    }

    #[test]
    fn zero_sigma_fallback_doubles_then_uses_ema() {
        let mut bc = cfg();
        bc.ema_beta = 0.5;
        let mut c = BatchController::new(bc);
        assert_eq!(c.requested(), 1);
        // no variance statistic at batch 1 -> geometric probe
        c.observe(&stats(1.0, 1.0, 0.0, 0.0), c.requested().min(4));
        assert_eq!(c.requested(), 2);
        c.observe(&stats(1.0, 1.0, 0.0, 0.0), c.requested().min(4));
        assert_eq!(c.requested(), 4);
        // now a real statistic arrives and seeds the EMA
        c.observe(&stats(1.0, 1.0, 6.4, 0.0), 4);
        assert!(c.requested() >= 10, "req {}", c.requested());
        // zero-sigma steps afterwards reuse the EMA instead of doubling
        let before = c.requested();
        c.observe(&stats(1.0, 1.0, 0.0, 0.0), c.requested().min(4));
        assert!(c.requested() >= before);
        assert!(c.requested() < before * 2, "must not blind-double with history");
    }

    #[test]
    fn non_adaptive_is_frozen() {
        let mut bc = cfg();
        bc.adaptive = false;
        bc.initial_batch = 5;
        let mut c = BatchController::new(bc);
        c.observe(&stats(1.0, 0.001, 100.0, 0.0), 4);
        assert_eq!(c.requested(), 5);
    }

    #[test]
    fn switch_mode_thresholds() {
        let ladder = [1, 2, 4, 8, 16];
        // paper: n=2, max_batch=16 -> accumulate only above 32
        let p = plan_step(32, 16, 2.0, true, &ladder);
        assert_eq!(
            p,
            StepPlan { micro_batch: 16, accum_steps: 1, switched: false, clamped: false }
        );
        let p = plan_step(33, 16, 2.0, true, &ladder);
        assert!(p.switched);
        assert_eq!(p.micro_batch, 16);
        assert_eq!(p.accum_steps, 3); // ceil(33/16)
        assert_eq!(p.effective_batch(), 48);
    }

    /// SAT1: Algorithm 3's switch test is the *strict* inequality
    /// `b_req > n·max_batch` — pinned on both sides of the rung, for an
    /// integer and a fractional multiplier. `b_req == threshold` exactly
    /// must stay at full update frequency.
    #[test]
    fn switch_mode_threshold_boundary() {
        let ladder = [1, 2, 4, 8, 16];
        // integer threshold: n=2, max=16 -> rung at 32
        let at = plan_step(32, 16, 2.0, true, &ladder);
        assert!(!at.switched, "b_req == threshold must not switch");
        assert_eq!(at.effective_batch(), 16, "clamped to max_batch, one update");
        let above = plan_step(33, 16, 2.0, true, &ladder);
        assert!(above.switched, "threshold + 1 is the first switching request");
        assert_eq!(above.accum_steps, 3);
        // fractional threshold: n=2.5, max=10 -> floor(25.0) = 25; the
        // integer request 25 equals the real threshold -> no switch, and
        // 26 is the first request strictly above it
        let at = plan_step(25, 10, 2.5, true, &ladder);
        assert!(!at.switched);
        let above = plan_step(26, 10, 2.5, true, &ladder);
        assert!(above.switched);
        assert_eq!(above.accum_steps, 3); // ceil(26/10)
        // fractional threshold that is not attained by any integer:
        // n=2.45, max=10 -> floor(24.5) = 24; 24 stays, 25 switches
        assert!(!plan_step(24, 10, 2.45, true, &ladder).switched);
        assert!(plan_step(25, 10, 2.45, true, &ladder).switched);
    }

    /// SAT1: ladder saturation raises the clamp flag instead of capping
    /// silently; the intended SwitchMode dead-zone clamp does not.
    #[test]
    fn ladder_saturation_sets_clamp_flag() {
        // top rung 8 < max_batch 12: the hardware budget is unreachable
        let sparse = [1, 2, 4, 8];
        let p = plan_step(6, 12, 2.0, true, &sparse);
        assert!(!p.clamped, "request on the ladder is not a clamp");
        let p = plan_step(12, 12, 2.0, true, &sparse);
        assert!(p.clamped, "rounding 12 saturates at rung 8");
        assert_eq!(p.micro_batch, 8);
        let p = plan_step(40, 12, 2.0, true, &sparse);
        assert!(p.switched && p.clamped, "switched accumulation still under-runs");
        assert_eq!(p.micro_batch, 8);
        assert_eq!(p.accum_steps, 4); // ceil(40/12) — arithmetic unchanged
        assert!(p.effective_batch() < 40, "the flag marks the silent shortfall");

        // full ladder: the dead zone (max < b_req <= n·max) is the
        // *intended* clamp-to-max_batch, not a ladder saturation
        let full = [1, 2, 4, 8, 16];
        let p = plan_step(20, 16, 2.0, true, &full);
        assert!(!p.switched && !p.clamped);
        assert_eq!(p.micro_batch, 16);
        // switch disabled: ladder covers the budget -> no flag either
        let p = plan_step(1000, 16, 2.0, false, &full);
        assert!(!p.clamped);
        // but a saturated ladder below the budget always flags
        let p = plan_step(1000, 16, 2.0, false, &sparse);
        assert!(p.clamped);
    }

    #[test]
    fn switch_disabled_clamps() {
        let ladder = [1, 2, 4, 8, 16];
        let p = plan_step(1000, 16, 2.0, false, &ladder);
        assert_eq!(
            p,
            StepPlan { micro_batch: 16, accum_steps: 1, switched: false, clamped: false }
        );
    }

    #[test]
    fn plan_rounds_up_to_rung() {
        let ladder = [1, 2, 4, 8, 16];
        let p = plan_step(3, 16, 2.0, true, &ladder);
        assert_eq!(p.micro_batch, 4);
        assert_eq!(p.accum_steps, 1);
        // rounding never exceeds max_batch even with a sparse ladder
        let p = plan_step(9, 12, 2.0, true, &[1, 2, 4, 8, 16]);
        assert_eq!(p.micro_batch, 12.min(16)); // rung 16 capped at max 12
    }

    #[test]
    fn degenerate_gradient_requests_huge_batch() {
        let mut c = BatchController::new(cfg());
        c.observe(&stats(1.0, 0.0, 5.0, 0.0), 1);
        // s1 == 0 => no finite request; geometric probe applies
        assert_eq!(c.requested(), 2);
    }
}
