//! Run metrics: per-step records, evaluation curves, communication
//! accounting, JSONL/CSV export, and run summaries.
//!
//! Everything Fig. 1 / Fig. 2 / the theory benches plot flows through the
//! `Recorder`; the export format is line-oriented so the report
//! generators (and any external plotting) can stream it. The fleet-scale
//! bench additionally distills its recorded streams into the
//! `bench_results/BENCH_fig6.json` perf artifact (EXPERIMENTS.md §Perf)
//! via `benchkit::write_json_artifact`.

use crate::util::JsonValue;
use anyhow::{Context, Result};
use std::io::Write;

/// One inner optimizer step of one worker.
#[derive(Clone, Debug)]
pub struct StepRecord {
    /// Global inner-step counter across the whole run.
    pub global_step: u64,
    /// Outer step (1-based) the inner step ran inside.
    pub outer_step: u64,
    /// Trainer id.
    pub trainer: usize,
    /// Worker position within the trainer.
    pub worker: usize,
    /// Micro-batch each engine call executed at.
    pub batch: usize,
    /// Controller-requested batch after folding this step in.
    pub requested_batch: usize,
    /// SwitchMode accumulation depth (1 = plain step).
    pub accum_steps: usize,
    /// True when the AOT batch ladder saturated below the hardware
    /// budget and silently capped this step's effective batch under the
    /// request (the `round_to_ladder` clamp — `batching::plan_step`).
    pub clamped: bool,
    /// Mean training loss observed by the step.
    pub loss: f64,
    /// ||mean gradient||^2 statistic of the step.
    pub grad_sq_norm: f64,
    /// Estimated per-sample gradient variance of the step.
    pub sigma2: f64,
    /// Worker virtual clock when the step completed.
    pub virtual_time_s: f64,
}

/// One validation pass.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    /// Global inner-step counter at evaluation time.
    pub global_step: u64,
    /// Outer step the evaluation belongs to.
    pub outer_step: u64,
    /// Trainer whose parameters were evaluated.
    pub trainer: usize,
    /// Mean validation loss.
    pub loss: f64,
    /// exp(loss), clamped (see [`perplexity`]).
    pub perplexity: f64,
    /// Virtual time at which the evaluated parameters existed.
    pub virtual_time_s: f64,
    /// Ledger communication count at evaluation time.
    pub comm_count: usize,
    /// Ledger communication bytes at evaluation time.
    pub comm_bytes: u64,
}

/// What happened to an instance in a lifecycle event (DESIGN.md §9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LifecycleEvent {
    /// A fresh instance joined the pool on `node`.
    Spawned {
        /// Node the spawned instance's workers were placed on.
        node: usize,
    },
    /// A merge consumed the instance.
    Retired,
}

impl LifecycleEvent {
    /// Canonical lowercase name (JSONL `event` field).
    pub fn as_str(self) -> &'static str {
        match self {
            LifecycleEvent::Spawned { .. } => "spawned",
            LifecycleEvent::Retired => "retired",
        }
    }
}

/// One instance-lifecycle ledger entry (spawn / retire — DESIGN.md §9).
#[derive(Clone, Copy, Debug)]
pub struct LifecycleRecord {
    /// Outer step the event happened at.
    pub outer_step: u64,
    /// Instance the event concerns.
    pub instance: usize,
    /// What happened.
    pub event: LifecycleEvent,
    /// Live instances after the event.
    pub live_after: usize,
    /// Virtual time of the event.
    pub virtual_time_s: f64,
}

/// Per-outer-round pool census: the time-varying m(t) observable the
/// elastic theory estimates consume (DESIGN.md §9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoundRecord {
    /// Outer step (1-based).
    pub outer_step: u64,
    /// Instances live at the start of the round's inner phase.
    pub live_instances: usize,
}

/// A trainer-merge event (MIT DoMerge).
#[derive(Clone, Debug)]
pub struct MergeRecord {
    /// Outer step the merge round ran at.
    pub outer_step: u64,
    /// Trainers consumed by the merge.
    pub merged: Vec<usize>,
    /// Trainer that carries the merged parameters forward.
    pub representative: usize,
    /// Live trainers after the merge.
    pub trainers_left: usize,
    /// Virtual time of the post-merge barrier.
    pub virtual_time_s: f64,
}

/// End-of-run time budget of one worker: where its virtual seconds went
/// while its trainer was alive. `busy_s` is compute, `wait_s` is barrier
/// idling behind slower peers, `comm_s` is modeled transfer time, and
/// `preempted_s` is churn downtime. The idle-time axis of the paper's
/// dynamic-workload story ("increasing throughput and reducing idle
/// time") is `wait_s + preempted_s`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UtilRecord {
    /// Trainer id.
    pub trainer: usize,
    /// Worker position within the trainer.
    pub worker: usize,
    /// Simulated node the worker ran on.
    pub node: usize,
    /// Compute seconds.
    pub busy_s: f64,
    /// Barrier-wait seconds (idling behind slower peers).
    pub wait_s: f64,
    /// Modeled communication seconds.
    pub comm_s: f64,
    /// Communication seconds hidden under compute by the delayed-overlap
    /// mode (DESIGN.md §8) — never part of the worker's clocked time, so
    /// excluded from the utilization denominator. Zero in blocking mode.
    pub hidden_s: f64,
    /// Churn-preemption downtime seconds.
    pub preempted_s: f64,
    /// Capacity seconds the worker's slot spent with **no live instance
    /// assigned** (its trainer was retired by a merge) — freed capacity,
    /// distinct from `wait_s` (an owned worker idling behind peers) and
    /// from `preempted_s` (node downtime). Excluded from the
    /// utilization denominator: nobody was scheduled there. The elastic
    /// lifecycle (DESIGN.md §9) exists to shrink this bucket.
    pub vacant_s: f64,
}

impl UtilRecord {
    /// Idle seconds: barrier waiting plus churn preemption.
    pub fn idle_s(&self) -> f64 {
        self.wait_s + self.preempted_s
    }

    /// Busy fraction of the worker's accounted time (1.0 for a worker
    /// that never waited).
    pub fn utilization(&self) -> f64 {
        let total = self.busy_s + self.wait_s + self.comm_s + self.preempted_s;
        if total > 0.0 {
            self.busy_s / total
        } else {
            1.0
        }
    }
}

/// In-memory sink for every record stream a run produces.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    /// Per-inner-step records in canonical (trainer, step, worker) order.
    pub steps: Vec<StepRecord>,
    /// Evaluation curve.
    pub evals: Vec<EvalRecord>,
    /// Trainer-merge events.
    pub merges: Vec<MergeRecord>,
    /// Instance-lifecycle events: spawns and merge retirements
    /// (DESIGN.md §9). Empty streams for a frozen pool are normal —
    /// seed instances produce no lifecycle rows.
    pub lifecycle: Vec<LifecycleRecord>,
    /// Per-outer-round live-instance census — the measured m(t).
    pub rounds: Vec<RoundRecord>,
    /// Per-worker utilization, filled once at the end of a run.
    pub utilization: Vec<UtilRecord>,
    /// Free-form run annotations (config echo, engine info, ...).
    pub notes: Vec<(String, String)>,
    /// Host wall-clock seconds of the run (perf reporting; NOT part of
    /// the determinism contract — see DESIGN.md §6 and the speedup
    /// helpers in [`crate::benchkit`]).
    pub wall_clock_s: f64,
    /// Step records already flushed to disk by a [`RecordStreamer`]
    /// (`run.stream_records`) and dropped from `steps`. Folded back into
    /// [`Recorder::mean_batch`] so summaries survive the drain.
    pub drained_steps: u64,
    /// Sum of applied batch sizes over the drained steps.
    pub drained_batch_sum: f64,
}

impl Recorder {
    /// Empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach a free-form (key, value) annotation.
    pub fn note(&mut self, key: &str, value: impl Into<String>) {
        self.notes.push((key.to_string(), value.into()));
    }

    // ------------------------------------------------------------------
    // summaries
    // ------------------------------------------------------------------

    /// First eval at which perplexity <= target; returns (global_step,
    /// virtual_time_s, comm_count) — the paper's time-to-target metric.
    pub fn time_to_target(&self, target_ppl: f64) -> Option<(u64, f64, usize)> {
        self.evals
            .iter()
            .find(|e| e.perplexity <= target_ppl)
            .map(|e| (e.global_step, e.virtual_time_s, e.comm_count))
    }

    /// Perplexity of the last evaluation, if any.
    pub fn final_perplexity(&self) -> Option<f64> {
        self.evals.last().map(|e| e.perplexity)
    }

    /// Minimum perplexity over all evaluations, if any.
    pub fn best_perplexity(&self) -> Option<f64> {
        self.evals
            .iter()
            .map(|e| e.perplexity)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Mean applied batch size over all steps (hardware-utilization
    /// proxy). Counts steps already streamed to disk via their drained
    /// aggregates, so the summary is identical with and without
    /// `run.stream_records`.
    pub fn mean_batch(&self) -> f64 {
        let n = self.steps.len() as f64 + self.drained_steps as f64;
        if n == 0.0 {
            return 0.0;
        }
        let sum =
            self.steps.iter().map(|s| s.batch as f64).sum::<f64>() + self.drained_batch_sum;
        sum / n
    }

    /// (step, requested_batch) series — Theorem 1's E[b_k] observable.
    /// In-RAM records only: the theory benches that plot this never
    /// enable `run.stream_records`.
    pub fn batch_growth_series(&self) -> Vec<(u64, usize)> {
        self.steps.iter().map(|s| (s.global_step, s.requested_batch)).collect()
    }

    /// Total idle seconds (barrier waits + churn downtime) across all
    /// workers — the cluster-efficiency axis of the dynamic-workload
    /// scenarios.
    pub fn total_idle_s(&self) -> f64 {
        self.utilization.iter().map(|u| u.idle_s()).sum()
    }

    /// Total capacity seconds that sat with no live instance assigned.
    pub fn total_vacant_s(&self) -> f64 {
        self.utilization.iter().map(|u| u.vacant_s).sum()
    }

    /// Spawn events recorded over the run.
    pub fn spawn_count(&self) -> usize {
        self.lifecycle
            .iter()
            .filter(|l| matches!(l.event, LifecycleEvent::Spawned { .. }))
            .count()
    }

    /// Mean live instances over the recorded rounds (the time-averaged
    /// m(t); 0 when no round census was recorded).
    pub fn mean_live_instances(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.live_instances as f64).sum::<f64>()
            / self.rounds.len() as f64
    }

    /// Mean per-worker busy fraction (0 when no utilization was recorded).
    pub fn mean_utilization(&self) -> f64 {
        if self.utilization.is_empty() {
            return 0.0;
        }
        self.utilization.iter().map(|u| u.utilization()).sum::<f64>()
            / self.utilization.len() as f64
    }

    // ------------------------------------------------------------------
    // export
    // ------------------------------------------------------------------

    fn step_json(s: &StepRecord) -> JsonValue {
        JsonValue::obj(vec![
            ("type", JsonValue::str("step")),
            ("global_step", JsonValue::num(s.global_step as f64)),
            ("outer_step", JsonValue::num(s.outer_step as f64)),
            ("trainer", JsonValue::num(s.trainer as f64)),
            ("worker", JsonValue::num(s.worker as f64)),
            ("batch", JsonValue::num(s.batch as f64)),
            ("requested_batch", JsonValue::num(s.requested_batch as f64)),
            ("accum_steps", JsonValue::num(s.accum_steps as f64)),
            ("clamped", JsonValue::Bool(s.clamped)),
            ("loss", JsonValue::num(s.loss)),
            ("grad_sq_norm", JsonValue::num(s.grad_sq_norm)),
            ("sigma2", JsonValue::num(s.sigma2)),
            ("virtual_time_s", JsonValue::num(s.virtual_time_s)),
        ])
    }

    fn eval_json(e: &EvalRecord) -> JsonValue {
        JsonValue::obj(vec![
            ("type", JsonValue::str("eval")),
            ("global_step", JsonValue::num(e.global_step as f64)),
            ("outer_step", JsonValue::num(e.outer_step as f64)),
            ("trainer", JsonValue::num(e.trainer as f64)),
            ("loss", JsonValue::num(e.loss)),
            ("perplexity", JsonValue::num(e.perplexity)),
            ("virtual_time_s", JsonValue::num(e.virtual_time_s)),
            ("comm_count", JsonValue::num(e.comm_count as f64)),
            ("comm_bytes", JsonValue::num(e.comm_bytes as f64)),
        ])
    }

    /// Write all records as JSON-lines.
    pub fn write_jsonl(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let f = std::fs::File::create(path).with_context(|| format!("create {path}"))?;
        let mut w = std::io::BufWriter::new(f);
        self.write_notes(&mut w)?;
        Self::write_step_lines(&mut w, &self.steps)?;
        self.write_tail(&mut w)?;
        w.flush()?;
        Ok(())
    }

    /// Note lines — the canonical JSONL prefix (shared by the buffered
    /// writer and the streaming finisher so both emit identical bytes).
    fn write_notes<W: Write>(&self, w: &mut W) -> Result<()> {
        for (k, v) in &self.notes {
            let line = JsonValue::obj(vec![
                ("type", JsonValue::str("note")),
                ("key", JsonValue::str(k.clone())),
                ("value", JsonValue::str(v.clone())),
            ]);
            writeln!(w, "{}", line.to_string())?;
        }
        Ok(())
    }

    /// Step lines (one per record, canonical order = slice order).
    fn write_step_lines<W: Write>(w: &mut W, steps: &[StepRecord]) -> Result<()> {
        for s in steps {
            writeln!(w, "{}", Self::step_json(s).to_string())?;
        }
        Ok(())
    }

    /// Everything after the step block: evals, merges, lifecycle, rounds,
    /// perf, utilization — the canonical JSONL suffix.
    fn write_tail<W: Write>(&self, w: &mut W) -> Result<()> {
        for e in &self.evals {
            writeln!(w, "{}", Self::eval_json(e).to_string())?;
        }
        for m in &self.merges {
            let line = JsonValue::obj(vec![
                ("type", JsonValue::str("merge")),
                ("outer_step", JsonValue::num(m.outer_step as f64)),
                (
                    "merged",
                    JsonValue::Array(
                        m.merged.iter().map(|&i| JsonValue::num(i as f64)).collect(),
                    ),
                ),
                ("representative", JsonValue::num(m.representative as f64)),
                ("trainers_left", JsonValue::num(m.trainers_left as f64)),
                ("virtual_time_s", JsonValue::num(m.virtual_time_s)),
            ]);
            writeln!(w, "{}", line.to_string())?;
        }
        for l in &self.lifecycle {
            let mut fields = vec![
                ("type", JsonValue::str("lifecycle")),
                ("event", JsonValue::str(l.event.as_str())),
                ("outer_step", JsonValue::num(l.outer_step as f64)),
                ("instance", JsonValue::num(l.instance as f64)),
                ("live_after", JsonValue::num(l.live_after as f64)),
                ("virtual_time_s", JsonValue::num(l.virtual_time_s)),
            ];
            if let LifecycleEvent::Spawned { node } = l.event {
                fields.push(("node", JsonValue::num(node as f64)));
            }
            writeln!(w, "{}", JsonValue::obj(fields).to_string())?;
        }
        for r in &self.rounds {
            let line = JsonValue::obj(vec![
                ("type", JsonValue::str("round")),
                ("outer_step", JsonValue::num(r.outer_step as f64)),
                ("live_instances", JsonValue::num(r.live_instances as f64)),
            ]);
            writeln!(w, "{}", line.to_string())?;
        }
        if self.wall_clock_s > 0.0 {
            let line = JsonValue::obj(vec![
                ("type", JsonValue::str("perf")),
                ("wall_clock_s", JsonValue::num(self.wall_clock_s)),
            ]);
            writeln!(w, "{}", line.to_string())?;
        }
        for u in &self.utilization {
            let line = JsonValue::obj(vec![
                ("type", JsonValue::str("utilization")),
                ("trainer", JsonValue::num(u.trainer as f64)),
                ("worker", JsonValue::num(u.worker as f64)),
                ("node", JsonValue::num(u.node as f64)),
                ("busy_s", JsonValue::num(u.busy_s)),
                ("wait_s", JsonValue::num(u.wait_s)),
                ("comm_s", JsonValue::num(u.comm_s)),
                ("hidden_s", JsonValue::num(u.hidden_s)),
                ("preempted_s", JsonValue::num(u.preempted_s)),
                ("vacant_s", JsonValue::num(u.vacant_s)),
                ("utilization", JsonValue::num(u.utilization())),
            ]);
            writeln!(w, "{}", line.to_string())?;
        }
        Ok(())
    }

    /// Drain `self.steps` into a streamer-owned sink: fold the aggregate
    /// counters and clear the in-RAM buffer. (Separated from the IO so
    /// the streamer can call it after writing the lines.)
    fn fold_drained_steps(&mut self) {
        self.drained_steps += self.steps.len() as u64;
        self.drained_batch_sum += self.steps.iter().map(|s| s.batch as f64).sum::<f64>();
        self.steps.clear();
    }

    /// Write the eval curve as CSV (step, time, ppl, comms) — what the
    /// figure generators tabulate.
    pub fn write_eval_csv(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let f = std::fs::File::create(path).with_context(|| format!("create {path}"))?;
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "global_step,virtual_time_s,loss,perplexity,comm_count,comm_bytes")?;
        for e in &self.evals {
            writeln!(
                w,
                "{},{:.6},{:.6},{:.6},{},{}",
                e.global_step, e.virtual_time_s, e.loss, e.perplexity, e.comm_count, e.comm_bytes
            )?;
        }
        Ok(())
    }
}

/// Streaming JSONL sink for step records (`run.stream_records`,
/// ROADMAP item 3 tail: 10k workers × thousands of rounds would pin
/// every `StepRecord` in RAM for the whole run otherwise).
///
/// Step records are the only stream that grows per inner step — evals,
/// merges, lifecycle and rounds are O(rounds) and stay buffered (the
/// coordinator reads `recorder.merges` mid-run for checkpoint-retention
/// pins, and the summaries need the eval curve). The streamer appends
/// drained step lines to a `<final>.steps.part` segment file per round;
/// `finish` reassembles the final JSONL in the exact canonical order of
/// [`Recorder::write_jsonl`] (notes, steps, evals, merges, lifecycle,
/// rounds, perf, utilization) using the same line emitters, so the
/// streamed file is byte-identical to the buffered writer's
/// (`tests/stream_records.rs` pins this).
#[derive(Debug)]
pub struct RecordStreamer {
    final_path: String,
    part_path: String,
    part: std::io::BufWriter<std::fs::File>,
}

impl RecordStreamer {
    /// Open the step-segment sink for a run that will end up at
    /// `final_path`.
    pub fn create(final_path: &str) -> Result<Self> {
        if let Some(dir) = std::path::Path::new(final_path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let part_path = part_path_for(final_path);
        let f = std::fs::File::create(&part_path)
            .with_context(|| format!("create {part_path}"))?;
        Ok(RecordStreamer {
            final_path: final_path.to_string(),
            part_path,
            part: std::io::BufWriter::new(f),
        })
    }

    /// Append the recorder's buffered step records to the segment file,
    /// fold their aggregates, and drop them from RAM. Called once per
    /// outer round by the coordinator.
    pub fn drain(&mut self, rec: &mut Recorder) -> Result<()> {
        Recorder::write_step_lines(&mut self.part, &rec.steps)?;
        rec.fold_drained_steps();
        self.part.flush().context("flush step segment")?;
        Ok(())
    }

    /// Drain any remaining steps, then assemble the final JSONL file in
    /// the canonical record order and remove the segment file.
    pub fn finish(mut self, rec: &mut Recorder) -> Result<()> {
        self.drain(rec)?;
        let RecordStreamer { final_path, part_path, part } = self;
        drop(part);
        let f = std::fs::File::create(&final_path)
            .with_context(|| format!("create {final_path}"))?;
        let mut w = std::io::BufWriter::new(f);
        rec.write_notes(&mut w)?;
        let mut seg = std::fs::File::open(&part_path)
            .with_context(|| format!("reopen {part_path}"))?;
        std::io::copy(&mut seg, &mut w).context("copy step segment")?;
        rec.write_tail(&mut w)?;
        w.flush()?;
        std::fs::remove_file(&part_path).ok();
        Ok(())
    }
}

/// The live step-segment path [`RecordStreamer`] writes beside
/// `final_path`. The service's incremental record endpoint reads this
/// file while a streamed run is still executing (DESIGN.md §13).
pub fn part_path_for(final_path: &str) -> String {
    format!("{final_path}.steps.part")
}

/// Incremental JSONL cursor (DESIGN.md §13): the complete lines of
/// `path` starting at 0-based line index `from`, plus the next cursor
/// value (`from` + number of lines returned).
///
/// Only newline-terminated lines are served — a trailing fragment still
/// being flushed by a concurrent [`RecordStreamer::drain`] is withheld
/// until its newline lands, so a client never sees a torn record. A
/// missing file reads as an empty page (the run has not opened its sink
/// yet), which keeps polling clients unconditional.
pub fn read_jsonl_lines_from(path: &str, from: usize) -> Result<(Vec<String>, usize)> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok((Vec::new(), from)),
        Err(e) => return Err(e).with_context(|| format!("reading {path}")),
    };
    let text = std::str::from_utf8(&bytes).with_context(|| format!("{path} is not UTF-8"))?;
    let lines: Vec<String> = text
        .split_inclusive('\n')
        .filter(|l| l.ends_with('\n'))
        .skip(from)
        .map(|l| l.strip_suffix('\n').unwrap_or(l).to_string())
        .collect();
    let next = from + lines.len();
    Ok((lines, next))
}

/// Perplexity from a mean cross-entropy loss (clamped to avoid overflow
/// in early-training explosions).
pub fn perplexity(loss: f64) -> f64 {
    loss.min(30.0).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval(step: u64, ppl: f64, t: f64, comms: usize) -> EvalRecord {
        EvalRecord {
            global_step: step,
            outer_step: 0,
            trainer: 0,
            loss: ppl.ln(),
            perplexity: ppl,
            virtual_time_s: t,
            comm_count: comms,
            comm_bytes: comms as u64 * 100,
        }
    }

    #[test]
    fn time_to_target() {
        let mut r = Recorder::new();
        r.evals.push(eval(10, 100.0, 1.0, 1));
        r.evals.push(eval(20, 50.0, 2.0, 2));
        r.evals.push(eval(30, 20.0, 3.0, 3));
        assert_eq!(r.time_to_target(50.0), Some((20, 2.0, 2)));
        assert_eq!(r.time_to_target(10.0), None);
        assert_eq!(r.best_perplexity(), Some(20.0));
        assert_eq!(r.final_perplexity(), Some(20.0));
    }

    #[test]
    fn jsonl_roundtrip() {
        let mut r = Recorder::new();
        r.note("method", "adloco");
        r.steps.push(StepRecord {
            global_step: 1,
            outer_step: 0,
            trainer: 0,
            worker: 0,
            batch: 4,
            requested_batch: 7,
            accum_steps: 1,
            clamped: false,
            loss: 5.5,
            grad_sq_norm: 0.25,
            sigma2: 1.5,
            virtual_time_s: 0.1,
        });
        r.evals.push(eval(10, 90.0, 1.0, 1));
        r.merges.push(MergeRecord {
            outer_step: 3,
            merged: vec![1, 2],
            representative: 2,
            trainers_left: 3,
            virtual_time_s: 2.0,
        });
        let dir = std::env::temp_dir().join("adloco_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.jsonl");
        r.write_jsonl(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        for line in lines {
            JsonValue::parse(line).unwrap();
        }
        let csv = dir.join("evals.csv");
        r.write_eval_csv(csv.to_str().unwrap()).unwrap();
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.starts_with("global_step,"));
        assert_eq!(csv_text.lines().count(), 2);
    }

    #[test]
    fn utilization_accounting() {
        let u = UtilRecord {
            trainer: 0,
            worker: 1,
            node: 2,
            busy_s: 6.0,
            wait_s: 2.0,
            comm_s: 1.0,
            hidden_s: 0.5,
            preempted_s: 1.0,
            vacant_s: 4.0,
        };
        assert!((u.utilization() - 0.6).abs() < 1e-12);
        assert!((u.idle_s() - 3.0).abs() < 1e-12);
        let mut r = Recorder::new();
        assert_eq!(r.mean_utilization(), 0.0);
        r.utilization.push(u);
        r.utilization.push(UtilRecord { busy_s: 4.0, wait_s: 0.0, ..u });
        assert!((r.total_idle_s() - 4.0).abs() < 1e-12);
        assert!((r.mean_utilization() - (0.6 + 4.0 / 6.0) / 2.0).abs() < 1e-12);

        // utilization rows export as parseable jsonl
        let dir = std::env::temp_dir().join("adloco_metrics_util");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("util.jsonl");
        r.write_jsonl(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            let v = JsonValue::parse(line).unwrap();
            assert_eq!(v.get("type").and_then(|t| t.as_str()), Some("utilization"));
        }
    }

    #[test]
    fn lifecycle_and_round_streams_export_and_summarize() {
        let mut r = Recorder::new();
        assert_eq!(r.mean_live_instances(), 0.0);
        assert_eq!(r.spawn_count(), 0);
        r.lifecycle.push(LifecycleRecord {
            outer_step: 2,
            instance: 4,
            event: LifecycleEvent::Spawned { node: 1 },
            live_after: 5,
            virtual_time_s: 3.25,
        });
        r.lifecycle.push(LifecycleRecord {
            outer_step: 3,
            instance: 0,
            event: LifecycleEvent::Retired,
            live_after: 4,
            virtual_time_s: 5.5,
        });
        r.rounds.push(RoundRecord { outer_step: 1, live_instances: 4 });
        r.rounds.push(RoundRecord { outer_step: 2, live_instances: 5 });
        assert_eq!(r.spawn_count(), 1);
        assert!((r.mean_live_instances() - 4.5).abs() < 1e-12);
        let u = UtilRecord {
            trainer: 0,
            worker: 0,
            node: 0,
            busy_s: 1.0,
            wait_s: 0.0,
            comm_s: 0.0,
            hidden_s: 0.0,
            preempted_s: 0.0,
            vacant_s: 2.5,
        };
        r.utilization.push(u);
        assert!((r.total_vacant_s() - 2.5).abs() < 1e-12);
        assert_eq!(u.utilization(), 1.0, "vacant time is not the worker idling");

        let dir = std::env::temp_dir().join("adloco_metrics_lifecycle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lc.jsonl");
        r.write_jsonl(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // 2 lifecycle + 2 round + 1 utilization lines, all parseable
        assert_eq!(text.lines().count(), 5);
        let mut spawned_nodes = 0;
        for line in text.lines() {
            let v = JsonValue::parse(line).unwrap();
            if v.get("event").and_then(|e| e.as_str()) == Some("spawned") {
                assert_eq!(v.get("node").and_then(|n| n.as_f64()), Some(1.0));
                spawned_nodes += 1;
            }
        }
        assert_eq!(spawned_nodes, 1);
    }

    #[test]
    fn perplexity_clamps() {
        assert!((perplexity(2.0) - 2.0f64.exp()).abs() < 1e-12);
        assert!(perplexity(1e9).is_finite());
    }

    #[test]
    fn mean_batch_and_series() {
        let mut r = Recorder::new();
        for (i, b) in [2usize, 4, 6].iter().enumerate() {
            r.steps.push(StepRecord {
                global_step: i as u64,
                outer_step: 0,
                trainer: 0,
                worker: 0,
                batch: *b,
                requested_batch: *b + 1,
                accum_steps: 1,
                clamped: false,
                loss: 0.0,
                grad_sq_norm: 0.0,
                sigma2: 0.0,
                virtual_time_s: 0.0,
            });
        }
        assert!((r.mean_batch() - 4.0).abs() < 1e-12);
        assert_eq!(r.batch_growth_series()[2], (2, 7));
    }

    #[test]
    fn jsonl_cursor_serves_complete_lines_and_withholds_the_tail() {
        let dir = std::env::temp_dir().join(format!("adloco_cursor_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("records.jsonl");
        let path = path.to_str().unwrap().to_string();
        std::fs::remove_file(&path).ok();
        // a missing file reads as an empty page
        let (lines, next) = read_jsonl_lines_from(&path, 0).unwrap();
        assert!(lines.is_empty());
        assert_eq!(next, 0);
        // an unterminated tail is withheld until its newline lands
        std::fs::write(&path, "{\"a\":1}\n{\"b\":2}\n{\"c\":").unwrap();
        let (lines, next) = read_jsonl_lines_from(&path, 0).unwrap();
        assert_eq!(lines, vec!["{\"a\":1}", "{\"b\":2}"]);
        assert_eq!(next, 2);
        std::fs::write(&path, "{\"a\":1}\n{\"b\":2}\n{\"c\":3}\n").unwrap();
        let (lines, next) = read_jsonl_lines_from(&path, next).unwrap();
        assert_eq!(lines, vec!["{\"c\":3}"]);
        assert_eq!(next, 3);
        // a cursor past the end is a clean empty page, not an error
        let (lines, far) = read_jsonl_lines_from(&path, 10).unwrap();
        assert!(lines.is_empty());
        assert_eq!(far, 10);
        std::fs::remove_file(&path).ok();
    }
}
