//! Inner-optimizer learning-rate schedules.
//!
//! The paper trains with a fixed AdamW learning rate; real deployments of
//! the method (and the MicroLlama recipe it borrows) use warmup + decay.
//! The schedule composes with adaptive batching in an important way: as
//! the batch grows, steps get less frequent but less noisy, so decaying
//! lr on the *inner-step* axis (not wall-clock) keeps the two adaptation
//! mechanisms independent — which is what the coordinator does.

use crate::config::ScheduleConfig;

/// Evaluated per (global inner step of a worker).
#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    /// Flat lr.
    Constant,
    /// Linear warmup to the base lr over `warmup` steps, then flat.
    Warmup { warmup: u64 },
    /// Linear warmup then cosine decay to `min_frac * base` at `total`.
    WarmupCosine { warmup: u64, total: u64, min_frac: f64 },
    /// Multiply by `factor` every `every` steps.
    StepDecay { every: u64, factor: f64 },
}

impl Schedule {
    /// Compile a config block; `total_steps` backs the cosine horizon
    /// when the config leaves it 0.
    pub fn from_config(cfg: &ScheduleConfig, total_steps: u64) -> Schedule {
        match cfg.kind.as_str() {
            "constant" => Schedule::Constant,
            "warmup" => Schedule::Warmup { warmup: cfg.warmup_steps },
            "warmup_cosine" => Schedule::WarmupCosine {
                warmup: cfg.warmup_steps,
                total: if cfg.total_steps > 0 { cfg.total_steps } else { total_steps.max(1) },
                min_frac: cfg.min_frac,
            },
            "step_decay" => Schedule::StepDecay {
                every: cfg.decay_every.max(1),
                factor: cfg.decay_factor,
            },
            other => {
                crate::warn!("unknown schedule {other:?}; using constant");
                Schedule::Constant
            }
        }
    }

    /// lr multiplier at 1-based step `k`.
    pub fn factor(&self, k: u64) -> f64 {
        match *self {
            Schedule::Constant => 1.0,
            Schedule::Warmup { warmup } => {
                if warmup == 0 || k >= warmup {
                    1.0
                } else {
                    k as f64 / warmup as f64
                }
            }
            Schedule::WarmupCosine { warmup, total, min_frac } => {
                if warmup > 0 && k < warmup {
                    return k as f64 / warmup as f64;
                }
                let total = total.max(warmup + 1);
                let progress =
                    ((k - warmup) as f64 / (total - warmup) as f64).clamp(0.0, 1.0);
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * progress).cos());
                min_frac + (1.0 - min_frac) * cos
            }
            Schedule::StepDecay { every, factor } => factor.powi((k / every) as i32),
        }
    }

    /// Absolute lr at step `k` given the base learning rate.
    pub fn lr(&self, base: f64, k: u64) -> f64 {
        base * self.factor(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_one() {
        let s = Schedule::Constant;
        assert_eq!(s.factor(1), 1.0);
        assert_eq!(s.factor(1_000_000), 1.0);
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::Warmup { warmup: 10 };
        assert!((s.factor(1) - 0.1).abs() < 1e-12);
        assert!((s.factor(5) - 0.5).abs() < 1e-12);
        assert_eq!(s.factor(10), 1.0);
        assert_eq!(s.factor(100), 1.0);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = Schedule::WarmupCosine { warmup: 10, total: 110, min_frac: 0.1 };
        // during warmup
        assert!((s.factor(5) - 0.5).abs() < 1e-12);
        // at warmup end: full lr
        assert!((s.factor(10) - 1.0).abs() < 1e-12);
        // midpoint of the cosine: (1 + min)/2
        assert!((s.factor(60) - 0.55).abs() < 1e-9);
        // at/after total: min_frac
        assert!((s.factor(110) - 0.1).abs() < 1e-12);
        assert!((s.factor(500) - 0.1).abs() < 1e-12);
        // monotone decreasing after warmup
        let mut last = f64::INFINITY;
        for k in 10..=110 {
            let f = s.factor(k);
            assert!(f <= last + 1e-12);
            last = f;
        }
    }

    #[test]
    fn step_decay() {
        let s = Schedule::StepDecay { every: 100, factor: 0.5 };
        assert_eq!(s.factor(1), 1.0);
        assert_eq!(s.factor(99), 1.0);
        assert_eq!(s.factor(100), 0.5);
        assert_eq!(s.factor(250), 0.25);
    }

    #[test]
    fn lr_scales_base() {
        let s = Schedule::Warmup { warmup: 4 };
        assert!((s.lr(4e-4, 2) - 2e-4).abs() < 1e-18);
    }

    #[test]
    fn from_config_variants() {
        use crate::config::ScheduleConfig;
        let mk = |kind: &str| ScheduleConfig {
            kind: kind.into(),
            warmup_steps: 5,
            total_steps: 0,
            min_frac: 0.2,
            decay_every: 50,
            decay_factor: 0.7,
        };
        assert_eq!(Schedule::from_config(&mk("constant"), 100), Schedule::Constant);
        assert_eq!(
            Schedule::from_config(&mk("warmup"), 100),
            Schedule::Warmup { warmup: 5 }
        );
        assert_eq!(
            Schedule::from_config(&mk("warmup_cosine"), 100),
            Schedule::WarmupCosine { warmup: 5, total: 100, min_frac: 0.2 }
        );
        assert_eq!(
            Schedule::from_config(&mk("step_decay"), 100),
            Schedule::StepDecay { every: 50, factor: 0.7 }
        );
        // unknown falls back to constant
        assert_eq!(Schedule::from_config(&mk("bogus"), 100), Schedule::Constant);
    }
}
