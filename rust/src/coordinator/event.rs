//! The discrete-event scheduler (DESIGN.md §3.2) and the parallel
//! inner phase built on it (DESIGN.md §6): worker steps, sync and
//! merge arrivals consumed in virtual-time order, with canonical-order
//! flushes that keep the output bit-identical to the lockstep walk on
//! static clusters at any thread count.

use super::chain::{run_worker_chain, ChainCtx, ChainTask};
use super::Coordinator;
use crate::batching::StepPlan;
use crate::comm::CommKind;
use crate::engine::StepStats;
use crate::metrics::{EvalRecord, StepRecord};
use crate::simulator::{EventQueue, SimEvent};
use crate::trainer::Worker;
use anyhow::Result;
use std::collections::BTreeMap;

/// Per-trainer bookkeeping of one event-driven outer step.
pub(crate) struct TrainerRun {
    pub(crate) plan: StepPlan,
    /// Inner steps this trainer executes this outer step.
    pub(crate) target: u64,
    /// `inner_steps_done` at the start of the outer step.
    pub(crate) start_done: u64,
    /// Worker whose parameters mid-loop evals read (first active; worker
    /// 0 on a static cluster, matching the lockstep path).
    pub(crate) eval_worker: usize,
    pub(crate) n_active: usize,
    /// Completed steps: (step, worker, stats, completion time). Folded
    /// into the controller in canonical (step, worker) order at the
    /// outer boundary — the exact order the lockstep walk produces.
    pub(crate) stats: Vec<(u64, usize, StepStats, f64)>,
    /// Mid-loop evals buffered until the canonical flush, keyed by step.
    pub(crate) evals: Vec<(u64, EvalRecord)>,
    /// Pending mid-loop evals: step -> arrival times + params snapshot.
    pub(crate) pending: BTreeMap<u64, PendingEval>,
}

pub(crate) struct PendingEval {
    pub(crate) times: Vec<f64>,
    pub(crate) remaining: usize,
    pub(crate) params: Vec<f32>,
}

impl Coordinator {
    /// One outer step of the discrete-event scheduler. Returns true if
    /// the target perplexity was reached.
    ///
    /// Inner steps execute when their `StepDone` event pops — in virtual
    /// time order across all trainers and workers. Controller
    /// observations, step records and buffered evals are flushed in
    /// canonical (trainer, step, worker) order at the outer boundary,
    /// which is exactly the order the lockstep walk produces — together
    /// with per-worker RNG streams this makes the two schedulers
    /// bit-identical on static clusters.
    pub fn step_outer_event(&mut self, outer_t: u64) -> Result<bool> {
        // ---- churn: refresh worker activity, re-shard changed trainers --
        self.cluster.apply_churn(&mut self.trainers, &mut self.rng)?;

        // ---- merging (same cadence and selection as lockstep) -----------
        let mc = self.cfg.algo.merge.clone();
        let mut merge_freed = 0usize;
        if mc.enabled
            && self.live_trainers() > 1
            && mc.frequency > 0
            && outer_t % mc.frequency as u64 == 0
        {
            merge_freed = self.maybe_merge_event(outer_t)?;
        }

        // ---- elastic lifecycle (DESIGN.md §9): spawn controller +
        //      round census, shared verbatim with the lockstep walk ----
        self.elastic_boundary(outer_t, merge_freed)?;

        let h = self.cfg.algo.inner_steps as u64;
        let cap = self.cfg.run.max_inner_steps as u64;
        let live: Vec<usize> = (0..self.trainers.len())
            .filter(|&i| self.trainers[i].alive)
            .collect();
        let mut hit_target = false;

        // ---- per-trainer plans + bookkeeping ----------------------------
        let mut runs: Vec<Option<TrainerRun>> =
            (0..self.trainers.len()).map(|_| None).collect();
        for &ti in &live {
            self.trainers[ti].broadcast_params();
            let plan = self.plan_for(ti);
            let start_done = self.trainers[ti].inner_steps_done;
            let target = if cap == 0 {
                h
            } else {
                h.min(cap.saturating_sub(start_done).max(1))
            };
            let n_active = self.trainers[ti].workers.iter().filter(|w| w.active).count();
            let eval_worker = self.trainers[ti]
                .workers
                .iter()
                .position(|w| w.active)
                .unwrap_or(0);
            runs[ti] = Some(TrainerRun {
                plan,
                target,
                start_done,
                eval_worker,
                n_active,
                stats: Vec::with_capacity((target as usize) * n_active),
                evals: Vec::new(),
                pending: BTreeMap::new(),
            });
        }

        // ---- inner phase: serial event loop, or parallel worker chains
        //      when run.threads > 1 (bit-identical by construction —
        //      DESIGN.md §6, enforced by tests/determinism_parallel.rs)
        if self.threads > 1 {
            hit_target |= self.parallel_inner_phase(outer_t, &live, &mut runs)?;
        } else {
            hit_target |= self.event_inner_phase(outer_t, &live, &mut runs)?;
        }

        // ---- canonical flush: controller folds, step records, evals -----
        for &ti in &live {
            let mut r = match runs[ti].take() {
                Some(r) => r,
                None => continue,
            };
            if r.n_active == 0 {
                continue; // fully preempted: the trainer sat this one out
            }
            r.stats.sort_by_key(|&(s, w, _, _)| (s, w));
            for &(step, wi, ref stats, vt) in r.stats.iter() {
                let tr = &mut self.trainers[ti];
                tr.controller.observe(stats, r.plan.effective_batch());
                self.total_samples += r.plan.effective_batch() as u64;
                self.recorder.steps.push(StepRecord {
                    global_step: r.start_done + step,
                    outer_step: outer_t,
                    trainer: ti,
                    worker: wi,
                    batch: r.plan.micro_batch,
                    requested_batch: tr.controller.requested(),
                    accum_steps: r.plan.accum_steps,
                    clamped: r.plan.clamped,
                    loss: stats.loss,
                    grad_sq_norm: stats.grad_sq_norm,
                    sigma2: stats.sigma2,
                    virtual_time_s: vt,
                });
            }
            self.trainers[ti].inner_steps_done = r.start_done + r.target;
            r.evals.sort_by_key(|&(s, _)| s);
            for (_, rec) in r.evals {
                self.recorder.evals.push(rec);
            }
        }

        // ---- outer sync over active workers, in trainer order, priced
        //      by the comm layer (topology-aware: intra-group reduces +
        //      a leader round over the WAN under hierarchical). Delayed
        //      overlap posts the collective non-blocking and applies the
        //      previous round's update instead (DESIGN.md §8) ----------
        let param_bytes = (self.engine.param_count() * 4) as u64;
        for &ti in &live {
            let members: Vec<(usize, usize)> = self.trainers[ti]
                .workers
                .iter()
                .filter(|w| w.active)
                .map(|w| (w.clock_slot, w.node))
                .collect();
            if members.is_empty() {
                continue;
            }
            let slots: Vec<usize> = members.iter().map(|&(s, _)| s).collect();
            let member_nodes: Vec<usize> = members.iter().map(|&(_, n)| n).collect();
            let t_start = slots
                .iter()
                .map(|&s| self.cluster.clock.time(s))
                .fold(0.0_f64, f64::max);
            let factor = self
                .cluster
                .scenario
                .min_bandwidth_factor(member_nodes.iter().copied(), t_start);
            if self.overlap_delayed() {
                self.outer_sync_delayed(ti, &slots, &member_nodes, factor);
                continue;
            }
            let cost = self.comm.sync_cost(
                param_bytes,
                &member_nodes,
                &self.cluster.topology,
                factor,
            );
            let t_after = self.cluster.barrier_tracked(&slots, cost.time_s);
            self.comm
                .record(CommKind::OuterSync, &cost, t_after, self.total_samples);
            let tr = &mut self.trainers[ti];
            tr.outer_step_active(&mut self.delta_scratch);
        }

        // end-of-outer-step evaluation on the trainer parameters
        for &ti in &live {
            if self.trainers[ti].alive {
                let reached = self.evaluate_trainer_params(ti, outer_t)?;
                hit_target |= reached;
            }
        }
        Ok(hit_target)
    }

    /// The serial inner phase of one event-driven outer step: seed the
    /// queue with every active worker's first step, then consume events
    /// in virtual-time order. Returns true if a mid-loop evaluation hit
    /// the target perplexity.
    fn event_inner_phase(
        &mut self,
        outer_t: u64,
        live: &[usize],
        runs: &mut [Option<TrainerRun>],
    ) -> Result<bool> {
        let cap = self.cfg.run.max_inner_steps as u64;
        let eval_every = self.cfg.run.eval_every as u64;
        let mut hit_target = false;

        // ---- seed the queue with every active worker's first step -------
        let mut queue = EventQueue::new();
        // delayed overlap: surface each in-flight collective's completion
        // as a SyncComplete marker so the event trace shows when the
        // round-(k−1) transfer lands relative to round k's compute. Pure
        // bookkeeping — the apply itself happens at the outer boundary
        // (DESIGN.md §8), so popping the marker changes no numerics.
        if self.overlap_delayed() {
            for &ti in live {
                if let Some(p) = &self.pending_syncs[ti] {
                    queue.push(
                        p.handle.completes_at,
                        SimEvent::SyncComplete { trainer: ti },
                    );
                }
            }
        }
        // elastic lifecycle trace markers (DESIGN.md §9): surface this
        // round's boundary spawns/retirements in the event trace. Like
        // SyncComplete these are bookkeeping-only — the spawn/retire
        // already happened before the queue was seeded.
        for meta in self.registry.metas() {
            if meta.born_outer == outer_t {
                let t = self.trainers[meta.id.0]
                    .workers
                    .first()
                    .map(|w| self.cluster.clock.time(w.clock_slot))
                    .unwrap_or(0.0);
                queue.push(t, SimEvent::InstanceSpawned { instance: meta.id.0 });
            }
            if meta.retired_outer == Some(outer_t) {
                let t = self.trainers[meta.id.0]
                    .workers
                    .first()
                    .map(|w| self.cluster.clock.time(w.clock_slot))
                    .unwrap_or(0.0);
                queue.push(t, SimEvent::InstanceRetired { instance: meta.id.0 });
            }
        }
        for &ti in live {
            let plan = runs[ti].as_ref().unwrap().plan;
            for wi in 0..self.trainers[ti].workers.len() {
                if !self.trainers[ti].workers[wi].active {
                    continue;
                }
                let end = self.schedule_step_end(ti, wi, &plan);
                queue.push(end, SimEvent::StepDone { trainer: ti, worker: wi, step: 1 });
            }
        }

        // ---- consume events in virtual-time order -----------------------
        while let Some((t, ev)) = queue.pop() {
            match ev {
                SimEvent::StepDone { trainer: ti, worker: wi, step } => {
                    let slot = self.trainers[ti].workers[wi].clock_slot;
                    self.cluster.clock.advance_to(slot, t);
                    let (plan, target, start_done, eval_worker) = {
                        let r = runs[ti].as_ref().unwrap();
                        (r.plan, r.target, r.start_done, r.eval_worker)
                    };
                    let lr = self
                        .lr_schedule
                        .lr(self.cfg.algo.lr_inner, start_done + step);
                    let stats = self.exec_worker_step(ti, wi, &plan, lr)?;
                    runs[ti].as_mut().unwrap().stats.push((step, wi, stats, t));

                    // mid-loop eval bookkeeping: the eval runs once every
                    // active worker has completed this step (lockstep
                    // evaluates at the same logical point)
                    let eval_due = eval_every > 0
                        && step % eval_every == 0
                        && step <= target
                        && !(cap > 0 && start_done + step >= cap);
                    if eval_due {
                        let ready = {
                            let r = runs[ti].as_mut().unwrap();
                            let n_active = r.n_active;
                            let p = r.pending.entry(step).or_insert_with(|| PendingEval {
                                times: Vec::new(),
                                remaining: n_active,
                                params: Vec::new(),
                            });
                            p.times.push(t);
                            p.remaining -= 1;
                            p.remaining == 0
                        };
                        if wi == eval_worker {
                            let snap = self.trainers[ti].workers[wi].state.params.clone();
                            runs[ti]
                                .as_mut()
                                .unwrap()
                                .pending
                                .get_mut(&step)
                                .unwrap()
                                .params = snap;
                        }
                        if ready {
                            let pend = runs[ti]
                                .as_mut()
                                .unwrap()
                                .pending
                                .remove(&step)
                                .unwrap();
                            let vt =
                                pend.times.iter().fold(0.0f64, |acc, &x| acc.max(x));
                            let (loss, ppl) = self.compute_eval(&pend.params, outer_t)?;
                            hit_target |= self.cfg.run.target_ppl > 0.0
                                && ppl <= self.cfg.run.target_ppl;
                            let rec = EvalRecord {
                                global_step: start_done + step,
                                outer_step: outer_t,
                                trainer: ti,
                                loss,
                                perplexity: ppl,
                                virtual_time_s: vt,
                                comm_count: self.comm.ledger.count(),
                                comm_bytes: self.comm.ledger.total_bytes(),
                            };
                            runs[ti].as_mut().unwrap().evals.push((step, rec));
                        }
                    }

                    if step < target {
                        let end = self.schedule_step_end(ti, wi, &plan);
                        queue.push(
                            end,
                            SimEvent::StepDone { trainer: ti, worker: wi, step: step + 1 },
                        );
                    } else {
                        queue.push(t, SimEvent::SyncArrive { trainer: ti, worker: wi });
                    }
                }
                // Arrival/completion/lifecycle markers: the rendezvous
                // itself is the queue draining — every active worker has
                // posted its arrival by then — delayed-overlap
                // completions apply at the boundary, not at their pop,
                // and lifecycle markers only place boundary spawns/
                // retirements in the trace.
                SimEvent::SyncArrive { .. }
                | SimEvent::MergeArrive { .. }
                | SimEvent::SyncComplete { .. }
                | SimEvent::InstanceSpawned { .. }
                | SimEvent::InstanceRetired { .. } => {}
            }
        }
        Ok(hit_target)
    }

    /// The parallel inner phase (the tentpole of DESIGN.md §6): between
    /// the outer-step prologue and the sync/merge rendezvous, workers are
    /// fully independent — each owns its model state, data sampler and
    /// RNG streams — so their inner-step chains fan out across
    /// `run.threads` OS threads and join at the boundary. Chain outputs
    /// are applied in canonical (trainer, worker) order and mid-loop
    /// evaluations are computed after the join, which together with the
    /// canonical flush makes the result bit-identical to the serial
    /// event loop no matter how the OS schedules the pool.
    fn parallel_inner_phase(
        &mut self,
        outer_t: u64,
        live: &[usize],
        runs: &mut [Option<TrainerRun>],
    ) -> Result<bool> {
        // ---- launch parameters, copied out before the borrow split ------
        let mut metas: Vec<ChainTask> = Vec::new();
        for &ti in live {
            let r = runs[ti].as_ref().unwrap();
            for (wi, w) in self.trainers[ti].workers.iter().enumerate() {
                if !w.active {
                    continue;
                }
                metas.push(ChainTask {
                    ti,
                    wi,
                    slot: w.clock_slot,
                    node: w.node,
                    start_time: self.cluster.clock.time(w.clock_slot),
                    busy_start: self.cluster.busy_s[w.clock_slot],
                    preempted_start: self.cluster.preempted_s[w.clock_slot],
                    plan: r.plan,
                    target: r.target,
                    start_done: r.start_done,
                    // snapshots cost a param clone each: only arm them
                    // when a mid-loop eval boundary will actually land
                    // inside this chain's step range
                    snapshot_params: wi == r.eval_worker
                        && self.cfg.run.eval_every > 0
                        && r.target >= self.cfg.run.eval_every as u64,
                });
            }
        }

        // ---- pair tasks with exclusive worker borrows -------------------
        let ctx = ChainCtx {
            engine: self.engine.as_ref(),
            corpus: &self.corpus,
            nodes: &self.cluster.nodes,
            scenario: &self.cluster.scenario,
            lr_schedule: &self.lr_schedule,
            lr_inner: self.cfg.algo.lr_inner,
            step_jitter: self.cfg.cluster.step_jitter,
            eval_every: self.cfg.run.eval_every as u64,
            cap: self.cfg.run.max_inner_steps as u64,
            width: self.corpus.width(),
        };
        let mut tasks: Vec<(ChainTask, &mut Worker)> = Vec::with_capacity(metas.len());
        {
            let mut pending = metas.into_iter().peekable();
            for (ti, tr) in self.trainers.iter_mut().enumerate() {
                for (wi, w) in tr.workers.iter_mut().enumerate() {
                    if pending.peek().is_some_and(|m| m.ti == ti && m.wi == wi) {
                        tasks.push((pending.next().unwrap(), w));
                    }
                }
            }
        }

        // ---- fan out / join: the coordinator's persistent pool
        //      (DESIGN.md §14) — threads were spawned once at
        //      construction and parked between rounds; work-stealing
        //      claims mean uneven chains (stragglers, slow nodes) never
        //      strand a thread ----
        let pool = self.pool.as_ref().expect("worker pool present when threads > 1");
        let results: Vec<Result<super::chain::ChainOutput>> = pool.run(
            tasks
                .into_iter()
                .map(|(m, w)| move || run_worker_chain(ctx, m, w))
                .collect(),
        );
        let mut outputs = Vec::with_capacity(results.len());
        for r in results {
            outputs.push(r?);
        }
        // canonical application order (the scheduling order of the pool
        // must leave no trace)
        outputs.sort_by_key(|o| (o.ti, o.wi));

        // ---- apply: clocks, time accounting, step stats, snapshots ------
        let mut snaps_by_trainer: BTreeMap<usize, Vec<(u64, Vec<f32>)>> = BTreeMap::new();
        for o in outputs {
            self.cluster.clock.advance_to(o.slot, o.end_time);
            self.cluster.busy_s[o.slot] = o.busy_end;
            self.cluster.preempted_s[o.slot] = o.preempted_end;
            let r = runs[o.ti].as_mut().unwrap();
            for (step, stats, t) in o.stats {
                r.stats.push((step, o.wi, stats, t));
            }
            if !o.snaps.is_empty() {
                snaps_by_trainer.entry(o.ti).or_default().extend(o.snaps);
            }
        }

        // ---- mid-loop evaluations (deferred to the join; the eval RNG
        //      is keyed by (seed, outer_step) so timing leaves no trace) -
        let mut hit_target = false;
        for &ti in live {
            let snaps = match snaps_by_trainer.remove(&ti) {
                Some(s) => s,
                None => continue,
            };
            for (step, params) in snaps {
                let (global_step, vt) = {
                    let r = runs[ti].as_ref().unwrap();
                    let vt = r
                        .stats
                        .iter()
                        .filter(|&&(s, _, _, _)| s == step)
                        .map(|&(_, _, _, t)| t)
                        .fold(0.0f64, f64::max);
                    (r.start_done + step, vt)
                };
                let (loss, ppl) = self.compute_eval(&params, outer_t)?;
                hit_target |=
                    self.cfg.run.target_ppl > 0.0 && ppl <= self.cfg.run.target_ppl;
                let rec = EvalRecord {
                    global_step,
                    outer_step: outer_t,
                    trainer: ti,
                    loss,
                    perplexity: ppl,
                    virtual_time_s: vt,
                    comm_count: self.comm.ledger.count(),
                    comm_bytes: self.comm.ledger.total_bytes(),
                };
                runs[ti].as_mut().unwrap().evals.push((step, rec));
            }
        }
        Ok(hit_target)
    }

    /// Schedule the completion time of worker `wi`'s next inner step:
    /// current clock + duration, stretched by scenario stragglers and
    /// preemption windows. Accounts busy/preempted time.
    fn schedule_step_end(&mut self, ti: usize, wi: usize, plan: &StepPlan) -> f64 {
        let mut dt = self.step_duration(ti, wi, plan);
        {
            let w = &mut self.trainers[ti].workers[wi];
            dt *= self.cluster.scenario.straggler_factor(&mut w.time_rng);
        }
        let (slot, node) = {
            let w = &self.trainers[ti].workers[wi];
            (w.clock_slot, w.node)
        };
        let start = self.cluster.clock.time(slot);
        // traced speed timelines (DESIGN.md §11): a deterministic
        // compute-time multiplier sampled at step start. 1.0 (bitwise
        // identity) outside a trace.
        dt *= self.cluster.scenario.speed_factor(node, start);
        let (end, stall) = self.cluster.scenario.compute_span(node, start, dt);
        self.cluster.busy_s[slot] += dt;
        self.cluster.preempted_s[slot] += stall;
        end
    }
}
