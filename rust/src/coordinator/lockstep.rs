//! The lockstep reference walk (DESIGN.md §3.1): trainers and their
//! workers iterate in fixed program order. Retained as the bit-exact
//! regression anchor for the event scheduler and the parallel runtime.

use super::Coordinator;
use crate::batching::StepPlan;
use crate::comm::CommKind;
use crate::metrics::StepRecord;
use anyhow::Result;

impl Coordinator {
    /// One outer step of the lockstep reference walk. Returns true if the
    /// target perplexity was reached.
    pub fn step_outer(&mut self, outer_t: u64) -> Result<bool> {
        // ---- merging (Algorithm 3 lines 11-16) -------------------------
        let mc = self.cfg.algo.merge.clone();
        let mut merge_freed = 0usize;
        if mc.enabled
            && self.live_trainers() > 1
            && mc.frequency > 0
            && outer_t % mc.frequency as u64 == 0
        {
            merge_freed = self.maybe_merge(outer_t)?;
        }

        // ---- elastic lifecycle (DESIGN.md §9): spawn controller +
        //      round census, shared verbatim with the event scheduler --
        self.elastic_boundary(outer_t, merge_freed)?;

        // ---- inner loops ------------------------------------------------
        let h = self.cfg.algo.inner_steps;
        let live: Vec<usize> = (0..self.trainers.len())
            .filter(|&i| self.trainers[i].alive)
            .collect();
        let mut hit_target = false;

        for &ti in &live {
            self.trainers[ti].broadcast_params();
            let plan = self.plan_for(ti);
            for step_h in 1..=h {
                self.inner_step(ti, outer_t, &plan)?;
                // cap on total inner steps (profiling / quick runs)
                let cap = self.cfg.run.max_inner_steps as u64;
                if cap > 0 && self.trainers[ti].inner_steps_done >= cap {
                    break;
                }
                // periodic evaluation on worker-0's live parameters
                if self.cfg.run.eval_every > 0
                    && step_h % self.cfg.run.eval_every == 0
                {
                    let reached = self.evaluate(ti, outer_t)?;
                    hit_target |= reached;
                }
            }
        }

        // ---- outer sync (Algorithm 3 lines 40-44), priced by the comm
        //      layer: one collective round over the trainer's workers
        //      (topology-aware; flat ring == the historical formulas).
        //      Delayed overlap posts the collective non-blocking and
        //      applies the previous round's update one round late
        //      instead (DESIGN.md §8; one shared helper keeps the
        //      lockstep and event walks bit-for-bit identical) --------
        let param_bytes = (self.engine.param_count() * 4) as u64;
        for &ti in &live {
            let member_nodes: Vec<usize> =
                self.trainers[ti].workers.iter().map(|w| w.node).collect();
            let slots: Vec<usize> =
                self.trainers[ti].workers.iter().map(|w| w.clock_slot).collect();
            if self.overlap_delayed() {
                self.outer_sync_delayed(ti, &slots, &member_nodes, 1.0);
                continue;
            }
            let cost =
                self.comm
                    .sync_cost(param_bytes, &member_nodes, &self.cluster.topology, 1.0);
            let t_after = self.cluster.barrier_tracked(&slots, cost.time_s);
            self.comm
                .record(CommKind::OuterSync, &cost, t_after, self.total_samples);
            let tr = &mut self.trainers[ti];
            tr.outer_step(&mut self.delta_scratch);
        }

        // end-of-outer-step evaluation on the trainer parameters
        for &ti in &live {
            if self.trainers[ti].alive {
                let reached = self.evaluate_trainer_params(ti, outer_t)?;
                hit_target |= reached;
            }
        }
        Ok(hit_target)
    }

    /// One inner step of every worker of trainer `ti` (lockstep walk).
    fn inner_step(&mut self, ti: usize, outer_t: u64, plan: &StepPlan) -> Result<()> {
        let lr = self
            .lr_schedule
            .lr(self.cfg.algo.lr_inner, self.trainers[ti].inner_steps_done + 1);
        let n_workers = self.trainers[ti].workers.len();

        for wi in 0..n_workers {
            let stats = self.exec_worker_step(ti, wi, plan, lr)?;

            // virtual time: accum_steps micro-steps on this worker's node
            let mut dt = self.step_duration(ti, wi, plan);
            let (slot, node) = {
                let w = &self.trainers[ti].workers[wi];
                (w.clock_slot, w.node)
            };
            // traced speed timelines are deterministic, so lockstep can
            // express them — the same multiply as the event scheduler's
            // schedule_step_end, at the same step-start time
            dt *= self.cluster.scenario.speed_factor(node, self.cluster.clock.time(slot));
            self.cluster.clock.advance(slot, dt);
            self.cluster.busy_s[slot] += dt;

            // adaptive-batching statistics (Algorithm 3 line 31)
            let tr = &mut self.trainers[ti];
            tr.controller.observe(&stats, plan.effective_batch());

            self.total_samples += plan.effective_batch() as u64;
            let global_step = tr.inner_steps_done + 1;
            self.recorder.steps.push(StepRecord {
                global_step,
                outer_step: outer_t,
                trainer: ti,
                worker: wi,
                batch: plan.micro_batch,
                requested_batch: tr.controller.requested(),
                accum_steps: plan.accum_steps,
                clamped: plan.clamped,
                loss: stats.loss,
                grad_sq_norm: stats.grad_sq_norm,
                sigma2: stats.sigma2,
                virtual_time_s: self.cluster.clock.time(slot),
            });
        }
        self.trainers[ti].inner_steps_done += 1;
        Ok(())
    }
}
