//! The worker-chain primitives of the parallel execution runtime
//! (DESIGN.md §6): the single `exec_step` / `step_compute_time`
//! implementation every scheduler path calls, and the chain a pool
//! thread runs for one worker's whole inner loop of an outer round.
//!
//! The delayed-overlap mode (DESIGN.md §8) needs no changes here by
//! design: chains only ever run *between* outer boundaries, and both
//! the non-blocking post and the one-round-late apply sit at the
//! boundary where the coordinator is single-threaded — so a chain
//! cannot observe whether the parameters it was broadcast are fresh or
//! one update stale.

use crate::batching::StepPlan;
use crate::cluster::NodeModel;
use crate::data::{Corpus, TokenBatch};
use crate::engine::{StepStats, TrainEngine};
use crate::simulator::Scenario;
use crate::trainer::Worker;
use crate::util::Rng;
use anyhow::Result;

/// Shared read-only state a worker chain borrows from the coordinator
/// while it runs on a pool thread (DESIGN.md §6). `Copy` so each thread
/// captures its own handle.
#[derive(Clone, Copy)]
pub(crate) struct ChainCtx<'a> {
    pub(crate) engine: &'a dyn TrainEngine,
    pub(crate) corpus: &'a Corpus,
    pub(crate) nodes: &'a [NodeModel],
    pub(crate) scenario: &'a Scenario,
    pub(crate) lr_schedule: &'a crate::schedule::Schedule,
    pub(crate) lr_inner: f64,
    pub(crate) step_jitter: f64,
    pub(crate) eval_every: u64,
    pub(crate) cap: u64,
    pub(crate) width: usize,
}

/// Per-chain launch parameters, copied out of the coordinator before the
/// borrow split (everything here is plain data; the worker itself is the
/// one `&mut` the chain owns).
#[derive(Clone, Copy)]
pub(crate) struct ChainTask {
    pub(crate) ti: usize,
    pub(crate) wi: usize,
    pub(crate) slot: usize,
    pub(crate) node: usize,
    /// Worker virtual clock at the start of the outer step.
    pub(crate) start_time: f64,
    /// Carried-in busy/preempted accumulators: the chain continues the
    /// exact f64 addition sequence the serial loop would perform, so the
    /// utilization accounting stays bit-identical (DESIGN.md §6).
    pub(crate) busy_start: f64,
    pub(crate) preempted_start: f64,
    pub(crate) plan: StepPlan,
    pub(crate) target: u64,
    pub(crate) start_done: u64,
    /// True for the trainer's designated eval worker: snapshot parameters
    /// at each mid-loop evaluation step.
    pub(crate) snapshot_params: bool,
}

/// What one worker chain hands back to the coordinator at the join.
pub(crate) struct ChainOutput {
    pub(crate) ti: usize,
    pub(crate) wi: usize,
    pub(crate) slot: usize,
    /// (step, stats, completion time) for each executed inner step.
    pub(crate) stats: Vec<(u64, StepStats, f64)>,
    /// Parameter snapshots at mid-loop eval steps (eval worker only).
    pub(crate) snaps: Vec<(u64, Vec<f32>)>,
    pub(crate) end_time: f64,
    pub(crate) busy_end: f64,
    pub(crate) preempted_end: f64,
}

/// Per-step scratch the engine work writes through (`grad`/`accum` may
/// be empty when the plan never accumulates).
pub(crate) struct StepScratch<'a> {
    pub(crate) buf: &'a mut TokenBatch,
    pub(crate) grad: &'a mut [f32],
    pub(crate) accum: &'a mut [f32],
}

/// The engine work of one inner step of worker `w`: sample a batch (or
/// `accum_steps` of them under SwitchMode), run the gradient
/// computation, apply the update. THE single implementation — the
/// lockstep walk, the serial event loop and the parallel chains all
/// call this, so their numerics cannot drift apart (DESIGN.md §6).
/// Engine noise comes from the worker's private stream.
pub(crate) fn exec_step(
    engine: &dyn TrainEngine,
    corpus: &Corpus,
    w: &mut Worker,
    plan: &StepPlan,
    lr: f64,
    scratch: StepScratch<'_>,
) -> Result<StepStats> {
    if plan.accum_steps > 1 {
        // SwitchMode: accumulate accum_steps gradients at the micro
        // batch, then one optimizer commit (§4.2).
        scratch.accum.iter_mut().for_each(|x| *x = 0.0);
        let mut agg = StepStats::default();
        for _ in 0..plan.accum_steps {
            w.sampler.next_batch(corpus, scratch.buf);
            let s = engine.grad_step(
                &w.state.params,
                scratch.buf,
                scratch.grad,
                &mut w.noise_rng,
            )?;
            for (a, g) in scratch.accum.iter_mut().zip(scratch.grad.iter()) {
                *a += *g / plan.accum_steps as f32;
            }
            agg.loss += s.loss / plan.accum_steps as f64;
            agg.grad_sq_norm += s.grad_sq_norm / plan.accum_steps as f64;
            agg.sigma2 += s.sigma2 / plan.accum_steps as f64;
            agg.ip_var += s.ip_var / plan.accum_steps as f64;
        }
        engine.apply_update(&mut w.state, lr, scratch.accum)?;
        Ok(agg)
    } else {
        w.sampler.next_batch(corpus, scratch.buf);
        engine.train_step(&mut w.state, lr, scratch.buf, &mut w.noise_rng)
    }
}

/// Compute-time of one inner step (node model × accumulation depth ×
/// optional jitter from the worker's private time stream) — the single
/// implementation behind both schedulers and the parallel chains.
pub(crate) fn step_compute_time(
    node: &NodeModel,
    plan: &StepPlan,
    width: usize,
    jitter: f64,
    time_rng: &mut Rng,
) -> f64 {
    let mut dt = node.step_time(plan.micro_batch, width - 1) * plan.accum_steps as f64;
    if jitter > 0.0 {
        // truncated at -3 sigma so time never goes negative
        let z = time_rng.normal().clamp(-3.0, 3.0);
        dt *= (1.0 + jitter * z).max(0.05);
    }
    dt
}

/// Per-thread chain arena (DESIGN.md §14): the SwitchMode gradient
/// buffers and the token-batch cache a chain writes through, owned by
/// the pool thread and reused across every chain — and every round —
/// that thread ever runs, so a steady-state round performs zero
/// param-sized heap allocations. Chains never nest, so the `RefCell`
/// borrow is exclusive for a chain's whole duration.
#[derive(Default)]
struct ChainArena {
    grad: Vec<f32>,
    accum: Vec<f32>,
    /// One reusable buffer per (batch, width) shape — the shape set is
    /// bounded by the engine's batch ladder, so the cache stays tiny
    /// (mirrors the coordinator's serial-path `batch_bufs` cache).
    bufs: Vec<TokenBatch>,
}

impl ChainArena {
    fn batch_buf(&mut self, batch: usize, width: usize) -> usize {
        match self.bufs.iter().position(|b| b.batch == batch && b.width == width) {
            Some(i) => i,
            None => {
                self.bufs.push(TokenBatch::new(batch, width));
                self.bufs.len() - 1
            }
        }
    }
}

thread_local! {
    static CHAIN_ARENA: std::cell::RefCell<ChainArena> =
        std::cell::RefCell::new(ChainArena::default());
}

/// One worker's full inner-step chain for an outer round — the unit of
/// parallelism (DESIGN.md §6). Performs, draw for draw and flop for
/// flop, what the serial event loop executes for this worker, by
/// calling the same [`exec_step`] / [`step_compute_time`] /
/// `Scenario` primitives in the same per-stream order (time_rng:
/// jitter then straggler per step; noise_rng: engine draws per step;
/// virtual-time recurrence via `compute_span` from the previous step's
/// end). Scratch lives in the pool thread's [`ChainArena`], and chains
/// share nothing mutable across threads.
pub(crate) fn run_worker_chain(
    ctx: ChainCtx<'_>,
    task: ChainTask,
    w: &mut Worker,
) -> Result<ChainOutput> {
    CHAIN_ARENA.with(|arena| {
        let mut arena = arena.borrow_mut();
        run_worker_chain_in(ctx, task, w, &mut arena)
    })
}

fn run_worker_chain_in(
    ctx: ChainCtx<'_>,
    task: ChainTask,
    w: &mut Worker,
    arena: &mut ChainArena,
) -> Result<ChainOutput> {
    // re-tag in place: reuses the pool thread's tag buffer, no per-chain
    // String allocation (the tag is simply left behind after the chain —
    // pool threads only log while running a cell)
    crate::util::logger::set_thread_context_args(format_args!("t{}.w{}", task.ti, task.wi));
    let plan = task.plan;
    // arena-backed scratch; the gradient buffers are only needed on the
    // SwitchMode (accumulating) path. clear+resize re-zeroes the full
    // span — bit-identical to the fresh `vec![0.0f32; p]` this used to
    // allocate, but the capacity is retained across chains and rounds.
    if plan.accum_steps > 1 {
        let p = ctx.engine.param_count();
        arena.grad.clear();
        arena.grad.resize(p, 0.0);
        arena.accum.clear();
        arena.accum.resize(p, 0.0);
    }
    let bi = arena.batch_buf(plan.micro_batch, ctx.width);
    let ChainArena { grad, accum, bufs } = arena;
    let buf = &mut bufs[bi];
    let mut stats_out: Vec<(u64, StepStats, f64)> = Vec::with_capacity(task.target as usize);
    let mut snaps: Vec<(u64, Vec<f32>)> = Vec::new();
    let mut now = task.start_time;
    let mut busy = task.busy_start;
    let mut preempted = task.preempted_start;
    let node_model = &ctx.nodes[task.node];

    for step in 1..=task.target {
        // ---- timing (serial: step_duration + schedule_step_end) --------
        let mut dt =
            step_compute_time(node_model, &plan, ctx.width, ctx.step_jitter, &mut w.time_rng);
        dt *= ctx.scenario.straggler_factor(&mut w.time_rng);
        dt *= ctx.scenario.speed_factor(task.node, now);
        let (end, stall) = ctx.scenario.compute_span(task.node, now, dt);
        busy += dt;
        preempted += stall;
        now = end;

        // ---- compute (the shared exec_step, like the serial paths) -----
        let lr = ctx.lr_schedule.lr(ctx.lr_inner, task.start_done + step);
        let stats = exec_step(
            ctx.engine,
            ctx.corpus,
            w,
            &plan,
            lr,
            StepScratch { buf: &mut *buf, grad: &mut grad[..], accum: &mut accum[..] },
        )?;
        stats_out.push((step, stats, now));

        // ---- mid-loop eval snapshot (same gating as the serial loop) ---
        if task.snapshot_params
            && ctx.eval_every > 0
            && step % ctx.eval_every == 0
            && !(ctx.cap > 0 && task.start_done + step >= ctx.cap)
        {
            snaps.push((step, w.state.params.clone()));
        }
    }
    Ok(ChainOutput {
        ti: task.ti,
        wi: task.wi,
        slot: task.slot,
        stats: stats_out,
        snaps,
        end_time: now,
        busy_end: busy,
        preempted_end: preempted,
    })
}
