//! In-module coordinator suite: run-level behaviour of every method and
//! ablation arm, scheduler equivalence and parallel-runtime smoke checks
//! (the full suites live in `tests/`).

use super::*;
use crate::comm::CommKind;
use crate::config::presets;

fn mock_cfg() -> Config {
    let mut cfg = presets::mock_default();
    cfg.algo.outer_steps = 8;
    cfg.algo.inner_steps = 15;
    cfg.algo.lr_inner = 0.15; // converge fast enough that the norm
                              // test's request visibly grows in-test
    cfg.algo.num_trainers = 4;
    cfg.algo.workers_per_trainer = 2;
    cfg.algo.merge.frequency = 2;
    cfg.run.eval_every = 5;
    cfg
}

fn run_with(cfg: Config) -> (RunResult, Recorder, usize) {
    let engine = crate::engine::build_engine(&cfg).unwrap();
    let mut c = Coordinator::new(cfg, engine).unwrap();
    let r = c.run().unwrap();
    let rec = c.recorder.clone();
    (r, rec, c.live_trainers())
}

#[test]
fn adloco_run_descends_and_merges() {
    let (r, rec, live) = run_with(mock_cfg());
    assert!(r.best_ppl < rec.evals.first().unwrap().perplexity);
    assert!(live < 4, "merging should consolidate trainers");
    assert!(!rec.merges.is_empty());
    assert!(r.comm_count > 0);
    assert!(r.virtual_time_s > 0.0);
}

#[test]
fn frozen_pool_census_and_registry_mirror_the_run() {
    // elastic off: the registry mirrors the merge-shrunk pool without
    // touching the run (DESIGN.md §9); the census records every round
    let cfg = mock_cfg();
    let outer = cfg.algo.outer_steps as u64;
    let engine = crate::engine::build_engine(&cfg).unwrap();
    let mut c = Coordinator::new(cfg, engine).unwrap();
    let r = c.run().unwrap();
    assert_eq!(r.spawn_count, 0, "off ⇒ zero spawns");
    assert_eq!(c.recorder.rounds.len() as u64, outer);
    assert_eq!(
        c.recorder.rounds.first().unwrap().live_instances,
        4,
        "round 1 census sees the full seed pool"
    );
    assert_eq!(
        c.recorder.rounds.last().unwrap().live_instances,
        r.trainers_left,
        "final census equals the surviving pool"
    );
    assert!(r.mean_live_instances <= 4.0 && r.mean_live_instances >= 1.0);
    // registry lifecycle mirrors the merges: retired rows match the
    // merge records, live rows match the survivors
    let reg = c.registry();
    assert_eq!(reg.len(), 4, "no instance was ever added");
    assert_eq!(reg.live_count(), r.trainers_left);
    let retired: usize = c.recorder.merges.iter().map(|m| m.merged.len()).sum();
    assert_eq!(4 - reg.live_count(), retired);
    // retired slots accrued vacancy, live ones none
    assert!(r.total_vacant_s > 0.0);
}

#[test]
fn adaptive_batch_grows() {
    let (_, rec, _) = run_with(mock_cfg());
    let first_req = rec.steps.first().unwrap().requested_batch;
    let last_req = rec.steps.last().unwrap().requested_batch;
    assert!(
        last_req > first_req,
        "requested batch should grow: {first_req} -> {last_req}"
    );
}

#[test]
fn diloco_policy_disables_features() {
    let mut cfg = mock_cfg();
    cfg.algo.method = Method::DiLoCo;
    let resolved = resolve_policy(&cfg);
    assert!(!resolved.algo.batching.adaptive);
    assert!(!resolved.algo.merge.enabled);
    assert!(!resolved.algo.switch.enabled);

    let (r, rec, live) = run_with(cfg);
    assert_eq!(live, 4, "DiLoCo must not merge");
    assert!(rec.merges.is_empty());
    // fixed batch: every step at algo.fixed_batch
    let fixed = resolved.algo.fixed_batch;
    assert!(rec.steps.iter().all(|s| s.batch == fixed.min(16)));
    assert!(r.best_ppl.is_finite());
}

#[test]
fn localsgd_uses_average_outer() {
    let mut cfg = mock_cfg();
    cfg.algo.method = Method::LocalSgd;
    let resolved = resolve_policy(&cfg);
    assert_eq!(resolved.algo.outer_opt, crate::config::OuterOptKind::Average);
    let (r, _, _) = run_with(cfg);
    assert!(r.best_ppl.is_finite());
}

#[test]
fn switch_mode_engages_at_large_requests() {
    let mut cfg = mock_cfg();
    // tiny node budget + warm-started request past 2*max_batch forces
    // SwitchMode from the first plan
    for n in &mut cfg.cluster.nodes {
        n.max_batch = 2;
    }
    cfg.algo.batching.initial_batch = 10;
    cfg.algo.batching.max_request = 16; // bound accumulation depth
    cfg.algo.outer_steps = 8;
    let (_, rec, _) = run_with(cfg);
    assert!(
        rec.steps.iter().any(|s| s.accum_steps > 1),
        "switch mode never engaged"
    );
    // micro batch never exceeds the node budget
    assert!(rec.steps.iter().all(|s| s.batch <= 2));
}

#[test]
fn switch_disabled_never_accumulates() {
    let mut cfg = mock_cfg();
    for n in &mut cfg.cluster.nodes {
        n.max_batch = 2;
    }
    cfg.algo.batching.max_request = 16;
    cfg.algo.switch.enabled = false;
    let (_, rec, _) = run_with(cfg);
    assert!(rec.steps.iter().all(|s| s.accum_steps == 1));
}

#[test]
fn merge_preserves_param_dimension_and_counts() {
    let cfg = mock_cfg();
    let engine = crate::engine::build_engine(&cfg).unwrap();
    let mut c = Coordinator::new(cfg, engine).unwrap();
    let p = c.engine.param_count();
    for t in 1..=6u64 {
        c.step_outer(t).unwrap();
    }
    for tr in c.trainers.iter().filter(|t| t.alive) {
        assert_eq!(tr.params.len(), p);
    }
    // every merge recorded the surviving count correctly
    for m in &c.recorder.merges {
        assert!(m.trainers_left >= c.cfg.algo.merge.min_trainers);
    }
}

#[test]
fn min_trainers_floor_respected() {
    let mut cfg = mock_cfg();
    cfg.algo.merge.min_trainers = 3;
    cfg.algo.merge.w = 4;
    cfg.algo.outer_steps = 10;
    let (_, _, live) = run_with(cfg);
    assert!(live >= 3, "live {live} below min_trainers floor");
}

#[test]
fn comm_ledger_has_outer_syncs() {
    let cfg = mock_cfg(); // workers_per_trainer = 2 -> real syncs
    let engine = crate::engine::build_engine(&cfg).unwrap();
    let mut c = Coordinator::new(cfg, engine).unwrap();
    c.run().unwrap();
    assert!(c.ledger().count_kind(CommKind::OuterSync) > 0);
    // flat cluster: the single network is the WAN tier, so every byte
    // counts as WAN traffic (DESIGN.md §7)
    assert_eq!(c.ledger().wan_bytes(), c.ledger().total_bytes());
}

#[test]
fn deterministic_runs() {
    let (r1, rec1, _) = run_with(mock_cfg());
    let (r2, rec2, _) = run_with(mock_cfg());
    assert_eq!(r1.comm_count, r2.comm_count);
    assert_eq!(r1.total_samples, r2.total_samples);
    assert_eq!(rec1.evals.len(), rec2.evals.len());
    for (a, b) in rec1.evals.iter().zip(rec2.evals.iter()) {
        assert!((a.perplexity - b.perplexity).abs() < 1e-9);
    }
}

#[test]
fn random_merge_policy_runs_and_merges() {
    let mut cfg = mock_cfg();
    cfg.algo.merge.policy = crate::config::MergeSelect::Random;
    let (r, rec, live) = run_with(cfg);
    assert!(r.best_ppl.is_finite());
    assert!(live < 4, "random policy must still merge");
    assert!(!rec.merges.is_empty());
}

#[test]
fn target_ppl_stops_early() {
    let mut cfg = mock_cfg();
    cfg.run.target_ppl = 1e14; // above the e^30 perplexity clamp => trivially reached
    let (r, _, _) = run_with(cfg);
    assert!(r.time_to_target.is_some());
    assert!(r.total_inner_steps <= 15, "should stop within first outer step");
}

#[test]
fn virtual_time_monotone_in_steps() {
    let (_, rec, _) = run_with(mock_cfg());
    // per (trainer, worker) stream, virtual time must be nondecreasing
    use std::collections::HashMap;
    let mut last: HashMap<(usize, usize), f64> = HashMap::new();
    for s in &rec.steps {
        let key = (s.trainer, s.worker);
        if let Some(prev) = last.get(&key) {
            assert!(s.virtual_time_s >= *prev);
        }
        last.insert(key, s.virtual_time_s);
    }
}

#[test]
fn event_scheduler_matches_lockstep_exactly() {
    // The regression anchor of the event-driven refactor: on a static
    // cluster the two schedulers must produce bit-identical ledgers,
    // records and summaries (see also tests/event_scheduler.rs for
    // the config matrix).
    let mut lock_cfg = mock_cfg();
    lock_cfg.run.scheduler = crate::config::SchedulerKind::Lockstep;
    let mut ev_cfg = mock_cfg();
    ev_cfg.run.scheduler = crate::config::SchedulerKind::Event;

    let run = |cfg: Config| {
        let engine = crate::engine::build_engine(&cfg).unwrap();
        let mut c = Coordinator::new(cfg, engine).unwrap();
        let r = c.run().unwrap();
        (r, c.recorder.clone(), c.ledger().clone())
    };
    let (ra, reca, leda) = run(lock_cfg);
    let (rb, recb, ledb) = run(ev_cfg);

    assert_eq!(leda.count(), ledb.count(), "ledger event count");
    for (a, b) in leda.events.iter().zip(ledb.events.iter()) {
        assert_eq!(a.kind, b.kind);
        assert_eq!(a.scope, b.scope);
        assert_eq!(a.bytes, b.bytes);
        assert_eq!(a.participants, b.participants);
        assert_eq!(a.at_inner_step, b.at_inner_step);
        assert_eq!(
            a.at_virtual_s.to_bits(),
            b.at_virtual_s.to_bits(),
            "ledger timestamps must be bit-identical"
        );
    }
    assert_eq!(ra.total_samples, rb.total_samples);
    assert_eq!(ra.total_inner_steps, rb.total_inner_steps);
    assert_eq!(ra.trainers_left, rb.trainers_left);
    assert_eq!(ra.best_ppl.to_bits(), rb.best_ppl.to_bits());
    assert_eq!(ra.final_ppl.to_bits(), rb.final_ppl.to_bits());
    assert_eq!(ra.virtual_time_s.to_bits(), rb.virtual_time_s.to_bits());
    assert_eq!(reca.steps.len(), recb.steps.len());
    for (a, b) in reca.steps.iter().zip(recb.steps.iter()) {
        assert_eq!((a.global_step, a.trainer, a.worker), (b.global_step, b.trainer, b.worker));
        assert_eq!(a.requested_batch, b.requested_batch);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.virtual_time_s.to_bits(), b.virtual_time_s.to_bits());
    }
    assert_eq!(reca.evals.len(), recb.evals.len());
    for (a, b) in reca.evals.iter().zip(recb.evals.iter()) {
        assert_eq!(a.perplexity.to_bits(), b.perplexity.to_bits());
        assert_eq!(a.virtual_time_s.to_bits(), b.virtual_time_s.to_bits());
    }
}

#[test]
fn parallel_threads_match_serial_exactly() {
    // The parallel runtime's core invariant (DESIGN.md §6), in-module
    // smoke form; tests/determinism_parallel.rs holds the full suite.
    let mk = |threads: usize| {
        let mut cfg = mock_cfg();
        cfg.run.scheduler = crate::config::SchedulerKind::Event;
        cfg.run.threads = threads;
        cfg
    };
    let run = |cfg: Config| {
        let engine = crate::engine::build_engine(&cfg).unwrap();
        let mut c = Coordinator::new(cfg, engine).unwrap();
        let r = c.run().unwrap();
        (r, c.recorder.clone(), c.ledger().clone())
    };
    let (ra, reca, leda) = run(mk(1));
    let (rb, recb, ledb) = run(mk(4));
    assert_eq!(ra.best_ppl.to_bits(), rb.best_ppl.to_bits());
    assert_eq!(ra.virtual_time_s.to_bits(), rb.virtual_time_s.to_bits());
    assert_eq!(ra.total_idle_s.to_bits(), rb.total_idle_s.to_bits());
    assert_eq!(ra.total_samples, rb.total_samples);
    assert_eq!(leda.count(), ledb.count());
    for (a, b) in leda.events.iter().zip(ledb.events.iter()) {
        assert_eq!(a.at_virtual_s.to_bits(), b.at_virtual_s.to_bits());
    }
    assert_eq!(reca.steps.len(), recb.steps.len());
    for (a, b) in reca.steps.iter().zip(recb.steps.iter()) {
        assert_eq!((a.global_step, a.trainer, a.worker), (b.global_step, b.trainer, b.worker));
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.virtual_time_s.to_bits(), b.virtual_time_s.to_bits());
    }
    assert_eq!(reca.evals.len(), recb.evals.len());
    for (a, b) in reca.evals.iter().zip(recb.evals.iter()) {
        assert_eq!(a.perplexity.to_bits(), b.perplexity.to_bits());
    }
    assert_eq!(rb.threads, 4);
}

#[test]
fn utilization_is_recorded_and_sane() {
    let (r, rec, _) = run_with(mock_cfg());
    assert_eq!(rec.utilization.len(), 8, "4 trainers x 2 workers");
    assert!(rec.utilization.iter().all(|u| u.busy_s > 0.0));
    assert!(r.mean_utilization > 0.0 && r.mean_utilization <= 1.0);
    assert!(r.total_idle_s >= 0.0);
}

#[test]
fn straggler_scenario_runs_and_stretches_time() {
    let mk = |prob: f64| {
        let mut cfg = mock_cfg();
        cfg.run.scheduler = crate::config::SchedulerKind::Event;
        cfg.cluster.scenario.straggler_prob = prob;
        cfg.cluster.scenario.straggler_min = 2.0;
        cfg.cluster.scenario.straggler_max = 3.0;
        cfg
    };
    let (r0, _, _) = run_with(mk(0.0));
    let (r1, _, _) = run_with(mk(0.5));
    assert!(r1.best_ppl.is_finite());
    assert!(
        r1.virtual_time_s > r0.virtual_time_s,
        "stragglers must stretch virtual time: {} vs {}",
        r1.virtual_time_s,
        r0.virtual_time_s
    );
    assert_eq!(
        r0.total_samples, r1.total_samples,
        "stragglers change time, not the sample schedule"
    );
}

#[test]
fn churn_scenario_preempts_and_rejoins() {
    let mut cfg = mock_cfg();
    cfg.algo.merge.enabled = false; // isolate churn effects
    cfg.run.scheduler = crate::config::SchedulerKind::Event;
    // node 1 is down for a mid-run stretch of virtual time
    cfg.cluster.scenario.churn.push(crate::config::ChurnWindow {
        node: 1,
        from_s: 0.3,
        until_s: 1.2,
    });
    let engine = crate::engine::build_engine(&cfg).unwrap();
    let mut c = Coordinator::new(cfg, engine).unwrap();
    let r = c.run().unwrap();
    assert!(r.best_ppl.is_finite());
    c.record_utilization();
    let preempted: f64 = c.recorder.utilization.iter().map(|u| u.preempted_s).sum();
    assert!(preempted > 0.0, "preemption must be accounted");
    // all workers are active again at the end (window long past)
    assert!(c.trainers.iter().flat_map(|t| t.workers.iter()).all(|w| w.active));
}

#[test]
fn hierarchical_topology_moves_bytes_off_the_wan() {
    // the tentpole invariant in-module: same schedule, same total
    // bytes formulas, strictly less WAN traffic under the two-level
    // topology (full suite: tests/topology.rs)
    let mut flat = presets::hierarchical_mit();
    flat.cluster.topology = crate::config::TopologyKind::Flat;
    flat.algo.outer_steps = 4;
    let mut hier = presets::hierarchical_mit();
    hier.algo.outer_steps = 4;
    let (rf, _, _) = run_with(flat);
    let (rh, _, _) = run_with(hier);
    assert_eq!(rf.wan_comm_bytes, rf.comm_bytes, "flat: every byte is WAN");
    assert!(
        rh.wan_comm_bytes < rf.wan_comm_bytes,
        "hierarchical must shrink WAN bytes: {} vs {}",
        rh.wan_comm_bytes,
        rf.wan_comm_bytes
    );
}
