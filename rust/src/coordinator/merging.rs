//! MIT merge rounds (paper Algorithms 1-2): candidate selection —
//! topology-aware under the hierarchical cluster (DESIGN.md §7) —
//! the barrier/rendezvous flavours of both schedulers, and the shared
//! parameter/shard consolidation.

use super::Coordinator;
use crate::comm::CommKind;
use crate::data::shard::union_shards;
use crate::merge::{check_merge_with_policy, do_merge_with_scratch, MergePolicy};
use crate::metrics::MergeRecord;
use crate::trainer::Trainer;
use anyhow::Result;

impl Coordinator {
    /// The node a trainer is "homed" on for topology purposes: its
    /// first worker's placement (static over the run; churn toggles
    /// activity, never placement).
    pub(crate) fn home_node(&self, ti: usize) -> usize {
        self.trainers[ti].workers[0].node
    }

    /// Pick the trainers to merge this round (Algorithm 1). Empty or a
    /// single id means no merge.
    ///
    /// Under the hierarchical topology, selection prefers trainers
    /// homed in the *same node group* — the cheap intra-group side of
    /// the MIT cost asymmetry (DESIGN.md §7): groups are scanned in
    /// ascending id and the first group that can merge wins; only when
    /// no group can merge alone does selection fall through to the
    /// flat (cross-WAN) rule. Flat clusters take the historical path
    /// unchanged.
    pub(crate) fn select_merge(&mut self) -> Vec<usize> {
        let requests: Vec<(usize, usize)> = self
            .trainers
            .iter()
            .filter(|t| t.alive)
            .map(|t| (t.id, t.requested_batch()))
            .collect();
        let policy = match self.cfg.algo.merge.policy {
            crate::config::MergeSelect::WorstByBatch => MergePolicy::WorstByBatch,
            crate::config::MergeSelect::Random => MergePolicy::Random,
        };
        let w = self.cfg.algo.merge.w;
        let min_keep = self.cfg.algo.merge.min_trainers;
        if self.cluster.topology.is_hierarchical() {
            let live_total = requests.len();
            for g in 0..self.cluster.topology.n_groups() {
                let sub: Vec<(usize, usize)> = requests
                    .iter()
                    .copied()
                    .filter(|&(id, _)| {
                        self.cluster.topology.group_of(self.home_node(id)) == g
                    })
                    .collect();
                if sub.len() < 2 {
                    continue;
                }
                // the global min_trainers floor restated for the group:
                // every trainer outside it survives a local merge
                let outside = live_total - sub.len();
                let local_keep = min_keep.saturating_sub(outside).max(1);
                let sel = check_merge_with_policy(&sub, w, local_keep, policy, &mut self.rng);
                if sel.len() >= 2 {
                    return sel;
                }
            }
        }
        check_merge_with_policy(&requests, w, min_keep, policy, &mut self.rng)
    }

    /// MIT merge round (Algorithms 1-2), lockstep flavour: selection, a
    /// plain barrier over every worker of the selected trainers, then the
    /// shared consolidation. The comm layer prices the gather ((k−1)·P
    /// flat; split into intra legs + a (G−1)·P WAN leg hierarchically).
    /// Returns the number of instances the merge retired (the
    /// respawn-after-merge budget — DESIGN.md §9).
    pub(crate) fn maybe_merge(&mut self, outer_t: u64) -> Result<usize> {
        let selected = self.select_merge();
        if selected.len() < 2 {
            return Ok(0);
        }
        self.registry.mark_merging(&selected);
        // a merge is a full rendezvous: any delayed outer update still in
        // flight for a participant drains (applies) first, so the merged
        // parameters include every posted collective (DESIGN.md §8)
        for &id in &selected {
            self.drain_pending(id);
        }

        // barrier every worker of the merging trainers + transfer time
        let param_bytes = (self.engine.param_count() * 4) as u64;
        let slots: Vec<usize> = selected
            .iter()
            .flat_map(|&id| self.trainers[id].workers.iter().map(|w| w.clock_slot))
            .collect();
        let homes: Vec<usize> = selected.iter().map(|&id| self.home_node(id)).collect();
        let cost = self
            .comm
            .merge_cost(param_bytes, &homes, &self.cluster.topology, 1.0);
        let t_after = self.cluster.barrier_tracked(&slots, cost.time_s);
        self.comm
            .record(CommKind::Merge, &cost, t_after, self.total_samples);
        self.perform_merge(outer_t, &selected, t_after)
    }

    /// MIT merge round (Algorithms 1-2), event flavour: the rendezvous
    /// start is the last active participant's clock, and the transfer
    /// runs at the slowest participating link's current bandwidth.
    /// Returns the number of instances the merge retired (DESIGN.md §9).
    pub(crate) fn maybe_merge_event(&mut self, outer_t: u64) -> Result<usize> {
        let selected = self.select_merge();
        if selected.len() < 2 {
            return Ok(0);
        }
        self.registry.mark_merging(&selected);
        // drain in-flight delayed updates of every participant before the
        // consolidation (same rule as the lockstep flavour — DESIGN.md §8)
        for &id in &selected {
            self.drain_pending(id);
        }

        let mut slots: Vec<usize> = Vec::new();
        let mut nodes: Vec<usize> = Vec::new();
        for &id in &selected {
            for w in &self.trainers[id].workers {
                if w.active {
                    slots.push(w.clock_slot);
                    nodes.push(w.node);
                }
            }
        }
        if slots.is_empty() {
            // every selected trainer is fully preempted: fall back to the
            // whole (frozen) cohort, like the lockstep barrier, instead of
            // recording a merge at virtual time ~0
            for &id in &selected {
                for w in &self.trainers[id].workers {
                    slots.push(w.clock_slot);
                    nodes.push(w.node);
                }
            }
        }
        let t_all = slots
            .iter()
            .map(|&s| self.cluster.clock.time(s))
            .fold(0.0f64, f64::max);

        let param_bytes = (self.engine.param_count() * 4) as u64;
        let factor = self
            .cluster
            .scenario
            .min_bandwidth_factor(nodes.iter().copied(), t_all);
        let homes: Vec<usize> = selected.iter().map(|&id| self.home_node(id)).collect();
        let cost = self
            .comm
            .merge_cost(param_bytes, &homes, &self.cluster.topology, factor);
        let t_after = self.cluster.barrier_tracked(&slots, cost.time_s);
        self.comm
            .record(CommKind::Merge, &cost, t_after, self.total_samples);
        self.perform_merge(outer_t, &selected, t_after)
    }

    /// The parameter/shard consolidation of a merge (Algorithm 2), after
    /// the participants' barrier produced `t_after`. Shared by both
    /// schedulers; the ledger entry is recorded by the caller. Returns
    /// the number of instances retired (the elastic respawn budget).
    pub(crate) fn perform_merge(
        &mut self,
        outer_t: u64,
        selected: &[usize],
        t_after: f64,
    ) -> Result<usize> {
        // weighted merge over the selected trainers' parameters
        let outcome = {
            // split borrows: collect (id, b_req) first, then build the
            // mutable member list in id order
            let reqs: Vec<(usize, usize)> = selected
                .iter()
                .map(|&id| (id, self.trainers[id].requested_batch()))
                .collect();
            let mut members: Vec<(usize, usize, &mut [f32])> = Vec::new();
            // safe split of multiple &mut trainers via split_at_mut walk
            let mut rest: &mut [Trainer] = &mut self.trainers;
            let mut base = 0usize;
            let mut sorted = selected.to_vec();
            sorted.sort_unstable();
            for id in sorted {
                let local = id - base;
                let tmp = rest;
                let (head, tail) = tmp.split_at_mut(local + 1);
                let tr = &mut head[local];
                let b = reqs.iter().find(|(i, _)| *i == id).unwrap().1;
                members.push((id, b, tr.params.as_mut_slice()));
                rest = tail;
                base = id + 1;
            }
            // coordinator-owned f64 accumulator, reused across every
            // merge boundary (disjoint field borrow from `trainers`)
            do_merge_with_scratch(&mut members, &mut self.merge_scratch)
        };

        // consume the non-representative trainers
        for &dead in &outcome.removed {
            self.trainers[dead].alive = false;
        }
        // the representative keeps the union of the merged shards and its
        // own optimizer trajectory (Algorithm 2 line 9); its outer
        // momentum is reset since the parameters jumped
        let shard_refs: Vec<&crate::data::Shard> = selected
            .iter()
            .map(|&id| &self.trainers[id].shard)
            .collect();
        let merged_shard = union_shards(&shard_refs);
        let rep = outcome.representative;
        {
            // re-split among the representative's active workers (all of
            // them on a static cluster); churned-out workers get fresh
            // samplers from the merged shard when they rejoin
            let active_ix: Vec<usize> = self.trainers[rep]
                .workers
                .iter()
                .enumerate()
                .filter(|(_, w)| w.active)
                .map(|(i, _)| i)
                .collect();
            let split_ix: Vec<usize> = if active_ix.is_empty() {
                (0..self.trainers[rep].workers.len()).collect()
            } else {
                active_ix
            };
            let worker_shards = merged_shard.split(split_ix.len());
            for (&w_ix, ws) in split_ix.iter().zip(worker_shards.into_iter()) {
                self.trainers[rep].workers[w_ix].sampler =
                    crate::data::BatchSampler::new(ws, self.rng.fork(0xABCD + rep as u64));
            }
            self.trainers[rep].shard = merged_shard;
            self.trainers[rep].outer.reset();
        }

        // lifecycle transitions (DESIGN.md §9): Merging resolves —
        // representative back to Active, consumed instances to Retired;
        // the registry also remembers the merge product for future
        // spawns to seed their parameters from
        self.registry.resolve_merge(rep, &outcome.removed, outer_t);
        for &dead in &outcome.removed {
            self.recorder.lifecycle.push(crate::metrics::LifecycleRecord {
                outer_step: outer_t,
                instance: dead,
                event: crate::metrics::LifecycleEvent::Retired,
                live_after: self.live_trainers(),
                virtual_time_s: t_after,
            });
        }

        crate::info!(
            "outer {outer_t}: merged {:?} -> representative {rep} ({} trainers left)",
            outcome.removed,
            self.live_trainers()
        );
        self.recorder.merges.push(MergeRecord {
            outer_step: outer_t,
            merged: outcome.removed.clone(),
            representative: rep,
            trainers_left: self.live_trainers(),
            virtual_time_s: t_after,
        });
        Ok(outcome.removed.len())
    }
}
