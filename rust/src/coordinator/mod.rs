//! The AdLoCo coordinator (paper Algorithm 3): the run loop that composes
//! adaptive batching, SwitchMode accumulation, multi-instance merging and
//! DiLoCo-style outer optimization over a simulated cluster.
//!
//! The same loop realizes every method and ablation arm in the paper via
//! the config knobs (see [`resolve_policy`]):
//!
//! | run                    | adaptive | merge | switch | outer opt |
//! |------------------------|----------|-------|--------|-----------|
//! | AdLoCo (full)          | on       | on    | on     | Nesterov  |
//! | DiLoCo baseline        | off      | off   | off    | Nesterov  |
//! | LocalSGD baseline      | off      | off   | off    | Average   |
//! | Fig. 2 −adaptive       | off      | on    | on     | Nesterov  |
//! | Fig. 2 −merge          | on       | off   | on     | Nesterov  |
//! | Fig. 2 −switch         | on       | on    | off    | Nesterov  |
//!
//! Timekeeping is virtual (DESIGN.md §3): compute advances each worker's
//! clock through the node's step-time model; outer syncs and merges are
//! barriers plus modeled collective time; the ledger records every
//! communication for the C(N) analyses (Theorem 2).
//!
//! Since the PR-3 layering (DESIGN.md §7) the coordinator is training
//! policy only; its former god-module responsibilities live in:
//!
//! * [`crate::cluster`] — *time and place*: node models, virtual
//!   clocks, barrier/utilization accounting, churn, and the
//!   flat/hierarchical topology;
//! * [`crate::comm`] — *cost and ledger*: network tiers, pluggable
//!   collectives, and the single code path every `CommEvent` flows
//!   through;
//! * [`lockstep`] / [`event`] (this module's submodules) — the two run
//!   loops; [`chain`] — the parallel worker-chain runtime;
//!   [`merging`] — MIT selection/rendezvous/consolidation.
//!
//! Two run loops drive the same numerics (DESIGN.md §3.1–§3.2):
//!
//! * **lockstep** — the reference walk: trainers and their workers are
//!   iterated in fixed program order. Retained as the bit-exact
//!   regression anchor.
//! * **event** — a discrete-event scheduler: workers post `StepDone`
//!   events into a priority queue and the coordinator consumes them in
//!   virtual-time order, with `SyncArrive`/`MergeArrive` rendezvous at
//!   the outer boundaries. On a static cluster it reproduces the
//!   lockstep run bit-for-bit (per-worker RNG streams make the numerics
//!   scheduling-order independent — DESIGN.md §3.4); with a
//!   `cluster.scenario` it models stragglers, node churn and
//!   time-varying links, and accounts per-worker busy/wait/preempted
//!   time for the utilization report.
//!
//! The event path additionally hosts the **parallel execution runtime**
//! (DESIGN.md §6): with `run.threads > 1`, each active worker's
//! inner-step chain for the outer round runs on a thread pool — workers
//! are independent between sync/merge rendezvous, own their RNG streams
//! and model state, and all records flush in canonical order, so a
//! parallel run is bit-identical to the serial one
//! (`tests/determinism_parallel.rs`). Threads buy wall-clock only; they
//! never change a result.
//!
//! Outer syncs come in two overlap flavours (DESIGN.md §8,
//! `comm.overlap`): the default **blocking** rendezvous (bit-identical
//! to every pre-overlap release), and the ACCO-style **delayed** mode
//! where the collective posts non-blocking and its outer update applies
//! one round late — round k+1 computes on parameters stale by one
//! update while round k's transfer drains concurrently, and workers
//! stall only for whatever residue the compute could not hide.

mod chain;
mod event;
mod lockstep;
mod merging;
#[cfg(test)]
mod tests;

use crate::batching::{plan_step, StepPlan};
use crate::cluster::{assign_workers, ClusterState};
use crate::comm::{CommKind, CommLayer, CommLedger, SyncHandle};
use crate::config::{Config, ElasticMode, Method, OverlapMode, SchedulerKind};
use crate::data::{make_shards, Corpus, CorpusSpec, TokenBatch};
use crate::engine::{StepStats, TrainEngine};
use crate::instances::{plan_spawns, InstanceRegistry, NodeLoad, Origin, SpawnBudget};
use crate::metrics::{
    perplexity, EvalRecord, LifecycleEvent, LifecycleRecord, RecordStreamer, Recorder,
    RoundRecord,
};
use crate::simulator::ScenarioSource;
use crate::trainer::Trainer;
use crate::util::{derive_seed, Rng};
use anyhow::Result;
use chain::{exec_step, step_compute_time, StepScratch};
use std::sync::{Arc, Condvar, Mutex};

/// Live progress counters a steered run publishes at every outer-round
/// boundary (DESIGN.md §13). Pure observability: reading them never
/// perturbs the run.
#[derive(Clone, Debug, Default)]
pub struct BoundaryProgress {
    /// Outer rounds completed so far.
    pub outer_steps_done: u64,
    /// The run's configured outer-step total.
    pub outer_steps_total: u64,
    /// Live instance census at the boundary.
    pub live_instances: usize,
    /// Virtual-time front across all worker clocks (seconds).
    pub virtual_time_s: f64,
    /// Samples consumed so far (the N axis of Theorem 2).
    pub total_samples: u64,
}

/// Steering handle shared between a driver (the `adloco serve` control
/// plane) and a running [`Coordinator`] (DESIGN.md §13).
///
/// The coordinator polls it once per outer round, at the same shared
/// boundary both schedulers cross (the `elastic_boundary` pattern), in
/// a fixed order: publish progress → park while paused → write any
/// requested v4 complete snapshot → honour a cancel. Because every
/// externally requested mutation lands at that boundary — and pause
/// only suspends host wall-clock, never virtual time — a steered run's
/// records and results stay bit-identical to the same config run
/// one-shot; a cancelled run is the exact prefix of the uncancelled
/// one. The order also guarantees a checkpoint requested before a
/// cancel is written at the cancel boundary, not dropped.
#[derive(Default)]
pub struct BoundaryControl {
    inner: Mutex<ControlInner>,
    cv: Condvar,
}

#[derive(Default)]
struct ControlInner {
    cancel: bool,
    paused: bool,
    checkpoint_request: Option<String>,
    checkpoints: Vec<(u64, String)>,
    progress: BoundaryProgress,
}

impl BoundaryControl {
    /// Fresh handle with nothing requested.
    pub fn new() -> Self {
        BoundaryControl::default()
    }

    /// Ask the run to stop at its next outer-round boundary. Also wakes
    /// a paused run so cancellation cannot deadlock behind a pause.
    pub fn request_cancel(&self) {
        self.lock().cancel = true;
        self.cv.notify_all();
    }

    /// True once a cancel has been requested.
    pub fn cancelled(&self) -> bool {
        self.lock().cancel
    }

    /// Park the run at its next boundary (`true`) or release it
    /// (`false`). Pausing costs host wall-clock only — virtual time and
    /// every record stream are untouched.
    pub fn set_paused(&self, paused: bool) {
        self.lock().paused = paused;
        self.cv.notify_all();
    }

    /// True while a pause is in force.
    pub fn paused(&self) -> bool {
        self.lock().paused
    }

    /// Ask for a v4 complete snapshot to `path` at the next boundary.
    /// A later request before the boundary replaces the pending one.
    pub fn request_checkpoint(&self, path: &str) {
        self.lock().checkpoint_request = Some(path.to_string());
    }

    /// Snapshots written so far, as `(outer_step, path)` in write order.
    pub fn checkpoints(&self) -> Vec<(u64, String)> {
        self.lock().checkpoints.clone()
    }

    /// The most recently published boundary counters.
    pub fn progress(&self) -> BoundaryProgress {
        self.lock().progress.clone()
    }

    /// Replace the published counters. The coordinator calls this at
    /// every boundary; the service also pre-publishes the schedule
    /// shape (`outer_steps_total`) at submit time so observers see it
    /// before the first round completes.
    pub fn publish(&self, p: BoundaryProgress) {
        self.lock().progress = p;
    }

    fn take_checkpoint_request(&self) -> Option<String> {
        self.lock().checkpoint_request.take()
    }

    fn record_checkpoint(&self, outer: u64, path: String) {
        self.lock().checkpoints.push((outer, path));
    }

    fn wait_while_paused(&self) {
        let mut g = self.lock();
        while g.paused && !g.cancel {
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, ControlInner> {
        // a panicked holder only ever held the lock for plain field
        // reads/writes; the state stays coherent, so recover the guard
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// A delayed outer update in flight (DESIGN.md §8): the non-blocking
/// collective's handle plus the outer delta it will apply one round
/// late. The delta is captured at post time because the workers' buffers
/// are overwritten by the next round's broadcast.
pub(crate) struct PendingSync {
    /// The in-flight collective (cost, post time, completion time).
    pub(crate) handle: SyncHandle,
    /// Δ = x_ref − mean(active workers), frozen at post time.
    pub(crate) delta: Vec<f32>,
    /// `total_samples` at post time — the C(N) axis stamp the ledger
    /// row carries when the collective completes.
    pub(crate) sent_samples: u64,
}

/// Outcome summary of a run (full series live in the recorder).
///
/// Every field except `wall_clock_s` and `threads` is covered by the
/// determinism contract (DESIGN.md §6): it is a pure function of the
/// config and must be bit-identical across schedulers and thread counts.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Config name the run was launched under.
    pub name: String,
    /// Coordination method (AdLoCo / DiLoCo / LocalSGD).
    pub method: Method,
    /// Best validation perplexity seen by any live trainer.
    pub best_ppl: f64,
    /// Perplexity of the last evaluation of the run.
    pub final_ppl: f64,
    /// Max per-trainer inner-step count reached.
    pub total_inner_steps: u64,
    /// Samples consumed across the run (the N axis of Theorem 2).
    pub total_samples: u64,
    /// Communication events recorded in the ledger.
    pub comm_count: usize,
    /// Total bytes moved across all recorded communications.
    pub comm_bytes: u64,
    /// Bytes that crossed the WAN tier — equal to `comm_bytes` on a
    /// flat cluster (its single network is the WAN of the topology
    /// comparison); strictly the cross-group leader traffic under the
    /// hierarchical topology (DESIGN.md §7).
    pub wan_comm_bytes: u64,
    /// Simulated wall-clock (max over worker virtual clocks).
    pub virtual_time_s: f64,
    /// Live trainers at the end (merging consolidates them).
    pub trainers_left: usize,
    /// Sum of barrier-wait + churn-preemption seconds across all workers
    /// (the cluster-efficiency axis of the dynamic-workload scenarios).
    pub total_idle_s: f64,
    /// Mean per-worker busy fraction.
    pub mean_utilization: f64,
    /// (step, time, comms) at which target_ppl was first reached, if ever.
    pub time_to_target: Option<(u64, f64, usize)>,
    /// Collective seconds hidden under compute by the delayed-overlap
    /// mode (DESIGN.md §8): per applied sync, `min(comm, time until the
    /// next boundary)` — the wall-clock the overlap saved versus
    /// blocking on the same schedule. Zero in blocking mode. Part of
    /// the determinism contract like every other payload field.
    pub overlap_hidden_s: f64,
    /// Instances the elastic lifecycle spawned over the run
    /// (DESIGN.md §9). Always 0 under `algo.elastic = off`.
    pub spawn_count: u64,
    /// Time-averaged live-instance count over the outer rounds — the
    /// measured m(t) of the elastic theory estimates (DESIGN.md §9).
    /// Equals the static pool size minus merge shrinkage when elastic
    /// is off.
    pub mean_live_instances: f64,
    /// Capacity seconds across all slots that sat with no live instance
    /// assigned (`UtilRecord::vacant_s` summed) — the freed-capacity
    /// waste the spawn controller exists to reclaim.
    pub total_vacant_s: f64,
    /// Host wall-clock seconds spent inside `Coordinator::run` — NOT part
    /// of the determinism contract (it varies run to run); the observable
    /// behind the §Perf speedup table.
    pub wall_clock_s: f64,
    /// Resolved thread count the run executed with (`run.threads`, with
    /// 0 resolved via `RUN_THREADS`). Not part of the determinism
    /// contract's compared payload, but parallel runs must reproduce the
    /// serial payload bit-for-bit.
    pub threads: usize,
}

/// Apply the method's policy constraints to a copy of the config
/// (DiLoCo = AdLoCo minus adaptivity/merging/switching; LocalSGD further
/// degrades the outer optimizer to plain averaging — §3.1, §3.2).
pub fn resolve_policy(cfg: &Config) -> Config {
    let mut out = cfg.clone();
    match cfg.algo.method {
        Method::AdLoCo => {}
        Method::DiLoCo => {
            out.algo.batching.adaptive = false;
            out.algo.merge.enabled = false;
            out.algo.switch.enabled = false;
        }
        Method::LocalSgd => {
            out.algo.batching.adaptive = false;
            out.algo.merge.enabled = false;
            out.algo.switch.enabled = false;
            out.algo.outer_opt = crate::config::OuterOptKind::Average;
        }
    }
    out
}

/// The AdLoCo run loop over the simulated cluster: owns the trainer pool,
/// the engine, the data pipeline, the recorders, and the two carved-out
/// layers — [`ClusterState`] (time & place) and [`CommLayer`] (cost &
/// ledger).
pub struct Coordinator {
    cfg: Config,
    engine: Box<dyn TrainEngine>,
    corpus: Corpus,
    val_corpus: Corpus,
    trainers: Vec<Trainer>,
    /// Time & place: virtual clocks, node models, scenario, topology,
    /// per-slot busy/wait/comm/preempted accounting.
    cluster: ClusterState,
    /// Cost & ledger: network tiers, collectives, every `CommEvent`.
    comm: CommLayer,
    /// Every record stream the run produces (steps, evals, merges,
    /// utilization, notes, wall-clock).
    pub recorder: Recorder,
    rng: Rng,
    /// Reusable buffers (hot path: no allocation per step).
    delta_scratch: Vec<f32>,
    grad_scratch: Vec<f32>,
    accum_scratch: Vec<f32>,
    /// One reusable token buffer per (batch, width) seen — bounded by the
    /// engine ladder, so interleaved trainers with different plans (the
    /// event scheduler) don't reallocate per step.
    batch_bufs: Vec<TokenBatch>,
    /// Samples consumed across the run (the N axis of Theorem 2).
    total_samples: u64,
    /// Per-trainer delayed outer updates in flight (DESIGN.md §8).
    /// Always all-`None` in blocking mode.
    pending_syncs: Vec<Option<PendingSync>>,
    /// Run-level sum of per-sync hidden collective seconds (the
    /// `RunResult::overlap_hidden_s` accumulator).
    overlap_hidden_s: f64,
    /// The elastic instance registry (DESIGN.md §9): lifecycle states,
    /// spawn bookkeeping, node capacities. Mirrors the pool for frozen
    /// (`elastic = off`) runs without ever touching their numerics.
    registry: InstanceRegistry,
    /// Σ live instances over the outer rounds driven so far (the
    /// numerator of `RunResult::mean_live_instances`; checkpointed so
    /// resumed runs report the uninterrupted value).
    live_rounds_sum: u64,
    /// Outer rounds driven so far (the denominator).
    rounds_count: u64,
    /// Inner-lr schedule (evaluated on each trainer's inner-step count).
    lr_schedule: crate::schedule::Schedule,
    /// Resolved thread count for the parallel runtime (>= 1).
    threads: usize,
    /// Host wall-clock of the last `run()` call (perf reporting only).
    run_wall_s: f64,
    /// Per-round step-record streaming sink (`run.stream_records`);
    /// None = keep everything buffered in the recorder.
    streamer: Option<RecordStreamer>,
    /// Service steering handle polled at every outer boundary
    /// (DESIGN.md §13); None = one-shot run, boundary untouched.
    control: Option<Arc<BoundaryControl>>,
    /// Persistent execution runtime (DESIGN.md §14): pool threads are
    /// spawned once here and parked between rounds;
    /// `parallel_inner_phase` reuses them every round. None when
    /// `threads <= 1` (serial paths never need it).
    pool: Option<crate::util::parallel::WorkerPool>,
    /// Reusable eval-parameter staging buffer: `evaluate` /
    /// `evaluate_trainer_params` copy into this instead of cloning a
    /// param vector per evaluation (DESIGN.md §14).
    eval_scratch: Vec<f32>,
    /// Reusable f64 accumulator for merge weighted averages
    /// ([`crate::merge::do_merge_with_scratch`]).
    merge_scratch: Vec<f64>,
    /// Recycled outer-delta buffers for the delayed-sync path: popped in
    /// `outer_sync_delayed`, pushed back when a `PendingSync` is
    /// applied, so steady-state overlap rounds allocate nothing.
    delta_pool: Vec<Vec<f32>>,
}

impl Coordinator {
    /// Build a coordinator (generates data, shards it, places workers).
    pub fn new(cfg: Config, engine: Box<dyn TrainEngine>) -> Result<Coordinator> {
        let cfg = resolve_policy(&cfg);
        cfg.validate()?;
        let a = &cfg.algo;

        let seq_width_minus1 = cfg.data.seq_len;
        let corpus = Corpus::generate(CorpusSpec::new(
            cfg.data.corpus_sequences,
            seq_width_minus1,
            cfg.data.vocab,
            cfg.data.zipf_s,
            cfg.data.seed,
        ));
        let val_corpus = Corpus::generate(CorpusSpec::new(
            cfg.data.val_sequences.max(engine.eval_batch()),
            seq_width_minus1,
            cfg.data.vocab,
            cfg.data.zipf_s,
            cfg.data.seed ^ 0xFACE,
        ));

        let mut rng = Rng::new(cfg.seed);
        let k = a.num_trainers;
        let m = a.workers_per_trainer;
        let shards = make_shards(corpus.len(), k, cfg.data.shard_fraction, &mut rng);
        let placement = assign_workers(k * m, cfg.cluster.nodes.len());

        let mut trainers = Vec::with_capacity(k);
        for (i, shard) in shards.into_iter().enumerate() {
            let nodes_of_workers: Vec<usize> =
                (0..m).map(|j| placement[i * m + j]).collect();
            trainers.push(Trainer::new(
                i,
                engine.as_ref(),
                a,
                shard,
                &nodes_of_workers,
                i * m,
                // trainer 0 uses the canonical init; others are
                // independent initializations (MIT §4.1)
                i as u64,
                &mut rng,
            ));
        }

        // per-node worker-slot capacity the spawn controller respects
        // (DESIGN.md §9): an explicit `elastic.node_capacity`, or the
        // densest initial packing (uniform across nodes — simulated
        // hosts are homogeneous in slot count)
        let node_capacity: Vec<usize> = {
            let n_nodes = cfg.cluster.nodes.len();
            let cap = if a.elastic.node_capacity > 0 {
                a.elastic.node_capacity
            } else {
                let mut counts = vec![0usize; n_nodes];
                for &node in &placement {
                    counts[node] += 1;
                }
                counts.iter().copied().max().unwrap_or(1).max(1)
            };
            vec![cap; n_nodes]
        };

        // resolve the scenario source (stochastic model, trace file, or
        // deterministic generator — DESIGN.md §11). Generators draw from
        // derive_seed streams, never `rng`, so resolving here does not
        // shift any training stream. Config validation covers the
        // statically-known cases; a loaded trace file's dynamics are
        // only known now, hence the runtime scheduler check.
        let scenario_source = ScenarioSource::resolve(&cfg.cluster, cfg.seed)?;
        let scenario = scenario_source.compile(cfg.cluster.nodes.len())?;
        if scenario.requires_event() && cfg.run.scheduler != SchedulerKind::Event {
            anyhow::bail!(
                "the resolved workload trace is dynamic (churn/link shifts/stragglers) \
                 and requires run.scheduler=event"
            );
        }

        let p = engine.param_count();
        let threads = cfg.run.effective_threads();
        let mut recorder = Recorder::new();
        recorder.note("engine", engine.name());
        recorder.note("method", a.method.as_str());
        recorder.note("config", cfg.name.clone());
        recorder.note("scheduler", cfg.run.scheduler.as_str());
        recorder.note("threads", threads.to_string());
        recorder.note("topology", cfg.cluster.topology.as_str());
        recorder.note("scenario_source", scenario_source.describe());

        Ok(Coordinator {
            cluster: ClusterState::new_with_scenario(&cfg.cluster, k * m, scenario),
            comm: CommLayer::new(&cfg.cluster),
            recorder,
            rng,
            registry: InstanceRegistry::seed(k, node_capacity),
            live_rounds_sum: 0,
            rounds_count: 0,
            delta_scratch: vec![0.0; p],
            grad_scratch: vec![0.0; p],
            accum_scratch: vec![0.0; p],
            batch_bufs: Vec::new(),
            total_samples: 0,
            pending_syncs: (0..k).map(|_| None).collect(),
            overlap_hidden_s: 0.0,
            lr_schedule: crate::schedule::Schedule::from_config(
                &cfg.algo.lr_schedule,
                (cfg.algo.outer_steps * cfg.algo.inner_steps) as u64,
            ),
            threads,
            run_wall_s: 0.0,
            streamer: None,
            control: None,
            pool: if threads > 1 {
                Some(crate::util::parallel::WorkerPool::new(threads))
            } else {
                None
            },
            eval_scratch: Vec::new(),
            merge_scratch: Vec::new(),
            delta_pool: Vec::new(),
            cfg,
            engine,
            corpus,
            val_corpus,
            trainers,
        })
    }

    /// The (policy-resolved) config this coordinator runs.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// The communication ledger accumulated so far.
    pub fn ledger(&self) -> &CommLedger {
        &self.comm.ledger
    }

    /// Bytes currently travelling in non-blocking collectives
    /// (DESIGN.md §8). Zero in blocking mode and after every run
    /// completes — the end-of-run drain retires all handles.
    pub fn in_flight_bytes(&self) -> u64 {
        self.comm.in_flight_bytes()
    }

    /// Resolved thread count of the parallel runtime (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Trainers still alive (not consumed by a merge).
    pub fn live_trainers(&self) -> usize {
        self.trainers.iter().filter(|t| t.alive).count()
    }

    /// The elastic instance registry: lifecycle states, spawn ledger,
    /// node capacities (DESIGN.md §9).
    pub fn registry(&self) -> &InstanceRegistry {
        &self.registry
    }

    // ------------------------------------------------------------------
    // elastic lifecycle (DESIGN.md §9)
    // ------------------------------------------------------------------

    /// The shared elastic outer-boundary phase, called by both
    /// schedulers at the same point (after the merge round, before the
    /// inner loops) so lockstep and event stay bit-identical: promote
    /// last round's spawns to Active, run the spawn controller, then
    /// take the round's live-instance census. Returns the ids spawned
    /// this round. Under `elastic = off` the controller is never
    /// consulted — only the (new-stream) census runs.
    pub(crate) fn elastic_boundary(
        &mut self,
        outer_t: u64,
        merge_freed: usize,
    ) -> Result<Vec<usize>> {
        self.registry.activate_spawned();
        let spawned = if self.cfg.algo.elastic.mode == ElasticMode::Off {
            Vec::new()
        } else {
            self.maybe_spawn(outer_t, merge_freed)?
        };
        let live = self.live_trainers();
        self.live_rounds_sum += live as u64;
        self.rounds_count += 1;
        self.recorder.rounds.push(RoundRecord { outer_step: outer_t, live_instances: live });
        Ok(spawned)
    }

    /// Consult the spawn controller over the accumulated per-node
    /// utilization statistics (all determinism-contract fields — every
    /// scheduler and thread count sees identical loads) and spawn the
    /// planned instances. `merge_freed` is the number of instances this
    /// round's merge retired (the respawn-after-merge budget).
    fn maybe_spawn(&mut self, outer_t: u64, merge_freed: usize) -> Result<Vec<usize>> {
        let e = self.cfg.algo.elastic.clone();
        let max_instances = if e.max_instances > 0 {
            e.max_instances
        } else {
            2 * self.cfg.algo.num_trainers
        };
        let n_nodes = self.cluster.nodes.len();
        let front = self.cluster.clock.max_time();
        // aggregate slot ownership + idle statistics per node over the
        // live instances (inactive workers still own their slots)
        let mut assigned = vec![0usize; n_nodes];
        let mut idle = vec![0.0f64; n_nodes];
        let mut accounted = vec![0.0f64; n_nodes];
        for tr in self.trainers.iter().filter(|t| t.alive) {
            for w in &tr.workers {
                let s = w.clock_slot;
                assigned[w.node] += 1;
                idle[w.node] += self.cluster.wait_s[s] + self.cluster.preempted_s[s];
                accounted[w.node] += self.cluster.busy_s[s]
                    + self.cluster.wait_s[s]
                    + self.cluster.comm_s[s]
                    + self.cluster.preempted_s[s];
            }
        }
        let loads: Vec<NodeLoad> = (0..n_nodes)
            .map(|n| NodeLoad {
                node: n,
                capacity: self.registry.node_capacity[n],
                assigned: assigned[n],
                idle_frac: if accounted[n] > 0.0 {
                    idle[n] / accounted[n]
                } else if assigned[n] == 0 {
                    1.0 // churn- or merge-freed capacity: fully idle
                } else {
                    0.0 // first round: no accounting yet
                },
                available: self.cluster.scenario.node_available(n, front),
            })
            .collect();
        let cooldown_ok = self.registry.last_spawn_outer == 0
            || outer_t >= self.registry.last_spawn_outer + e.cooldown_rounds as u64;
        let origin = match e.mode {
            ElasticMode::RespawnAfterMerge => Origin::MergeRespawn,
            _ => Origin::UtilSpawn,
        };
        let plan = plan_spawns(
            e.mode,
            e.idle_threshold,
            &loads,
            &SpawnBudget {
                live_instances: self.live_trainers(),
                max_instances,
                cooldown_ok,
                merge_freed,
                spawn_width: e.workers_per_spawn.max(1),
            },
        );
        let mut out = Vec::with_capacity(plan.len());
        for node in plan {
            out.push(self.spawn_instance(node, outer_t, origin)?);
        }
        Ok(out)
    }

    /// Materialize one spawned instance on `node` (DESIGN.md §9):
    /// parameters seeded from the last merge product (or the first live
    /// instance), fresh outer/controller state, a fresh shard drawn —
    /// like every other stream of the instance — from its private
    /// `derive_seed(seed, "instance=<id>")` RNG, and brand-new clock
    /// slots starting at the cluster front. Existing instances' streams
    /// and slots are untouched by construction.
    fn spawn_instance(&mut self, node: usize, outer_t: u64, origin: Origin) -> Result<usize> {
        let id = self.trainers.len();
        let mut irng = Rng::new(derive_seed(self.cfg.seed, &format!("instance={id}")));
        let src = self
            .registry
            .last_merge_rep
            .filter(|&r| self.trainers[r].alive)
            .or_else(|| (0..self.trainers.len()).find(|&i| self.trainers[i].alive));
        let params = match src {
            Some(s) => self.trainers[s].params.clone(),
            None => self.engine.init_state(id as u64).params,
        };
        let shard = make_shards(self.corpus.len(), 1, self.cfg.data.shard_fraction, &mut irng)
            .pop()
            .unwrap();
        let t_spawn = self.cluster.clock.max_time();
        let m = self.cfg.algo.elastic.workers_per_spawn.max(1);
        let slots: Vec<usize> = (0..m).map(|_| self.cluster.push_slot(t_spawn)).collect();
        let tr = Trainer::spawned(id, params, &self.cfg.algo, shard, node, &slots, &mut irng);
        self.trainers.push(tr);
        self.pending_syncs.push(None);
        let rid = self.registry.register_spawn(outer_t, t_spawn, origin);
        debug_assert_eq!(rid.0, id, "registry and trainer pool must append in lockstep");
        crate::info!(
            "outer {outer_t}: spawned instance {id} on node {node} at t={t_spawn:.2}s \
             ({} live)",
            self.live_trainers()
        );
        self.recorder.lifecycle.push(LifecycleRecord {
            outer_step: outer_t,
            instance: id,
            event: LifecycleEvent::Spawned { node },
            live_after: self.live_trainers(),
            virtual_time_s: t_spawn,
        });
        Ok(id)
    }

    /// Book the vacant capacity of every retired instance's frozen
    /// slots (satellite of DESIGN.md §9: freed capacity accrues to its
    /// own `vacant_s` bucket instead of vanishing or polluting wait_s).
    /// A vacancy window opens where a retired worker's clock froze and
    /// closes either at the run front or — FIFO per node — when a later
    /// spawn re-occupies the freed capacity on that node: each spawned
    /// worker slot reclaims at most one open window, so the elastic
    /// lifecycle measurably *shrinks* the vacant total it was built to
    /// reclaim. Pure function of contract state (registry birth times,
    /// frozen clocks), so schedulers, thread counts and resumed runs
    /// all agree — and the per-slot write is an assignment
    /// ([`ClusterState::set_vacant_window`]), so recomputing after a
    /// resume (even from a snapshot taken post-run) never double
    /// counts.
    fn accrue_vacant_all(&mut self) {
        let front = self.cluster.clock.max_time();
        // reclaim events: each spawned worker slot occupies one unit of
        // node capacity from its birth time on (chronological; the sort
        // is stable, so same-boundary spawns keep registry order)
        let mut reclaims: Vec<(f64, usize)> = Vec::new();
        for meta in self.registry.metas() {
            if meta.origin == Origin::Seed {
                continue;
            }
            for w in &self.trainers[meta.id.0].workers {
                reclaims.push((meta.born_at_s, w.node));
            }
        }
        reclaims.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut used = vec![false; reclaims.len()];
        // vacancy windows: retired instances' frozen slots, oldest first
        let mut windows: Vec<(f64, usize, usize)> = Vec::new();
        for tr in self.trainers.iter().filter(|t| !t.alive) {
            for w in &tr.workers {
                windows.push((self.cluster.clock.time(w.clock_slot), w.node, w.clock_slot));
            }
        }
        windows.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.2.cmp(&b.2)));
        for (start, node, slot) in windows {
            let mut end = front;
            for i in 0..reclaims.len() {
                let (t, n) = reclaims[i];
                if !used[i] && n == node && t >= start {
                    used[i] = true;
                    end = t;
                    break;
                }
            }
            self.cluster.set_vacant_window(slot, end);
        }
    }

    /// The effective hardware max_batch for a trainer: the smallest node
    /// budget among its workers, capped by the engine's compiled ladder.
    fn max_batch_for(&self, t: &Trainer) -> usize {
        let node_min = t
            .workers
            .iter()
            .map(|w| self.cluster.nodes[w.node].max_batch)
            .min()
            .unwrap_or(1);
        node_min.min(self.engine.max_batch()).max(1)
    }

    /// Run the full schedule (T outer steps of H inner steps), honouring
    /// the checkpoint/resume settings in `run` config.
    ///
    /// Scheduler/thread dispatch: serial lockstep keeps the reference
    /// walk; everything else goes through the event-equivalent path,
    /// which fans worker chains out across `run.threads` OS threads when
    /// threads > 1. A parallel lockstep run is legal because lockstep
    /// configs are static by validation and the event path is bit-equal
    /// to lockstep on static clusters (DESIGN.md §3.2, §6).
    pub fn run(&mut self) -> Result<RunResult> {
        let wall0 = std::time::Instant::now();
        let mut start = 1u64;
        if let Some(path) = self.cfg.run.resume_from.clone() {
            match crate::checkpoint::load_interchange(&path)? {
                crate::checkpoint::Interchange::Complete(cp) => {
                    start = cp.outer_step + 1;
                    self.restore(&cp)?;
                    crate::info!("resumed from {path} at outer step {}", cp.outer_step);
                }
                crate::checkpoint::Interchange::Minimal(m) => {
                    // warm-start: parameters + streams only; the
                    // schedule restarts from step 1
                    self.warm_start(&m)?;
                    crate::info!(
                        "warm-started from minimal checkpoint {path} \
                         (taken at outer step {}; schedule restarts)",
                        m.outer_step
                    );
                }
            }
        }
        let outer_steps = self.cfg.algo.outer_steps as u64;
        let every = self.cfg.run.checkpoint_every as u64;
        let keep = self.cfg.run.keep_checkpoints;
        let mut last_t = start.min(outer_steps);
        for t in start..=outer_steps {
            last_t = t;
            let hit = match self.cfg.run.scheduler {
                SchedulerKind::Lockstep if self.threads <= 1 => self.step_outer(t)?,
                _ => self.step_outer_event(t)?,
            };
            if let Some(streamer) = self.streamer.as_mut() {
                // flush this round's step records to disk and drop them
                // from RAM (run.stream_records)
                streamer.drain(&mut self.recorder)?;
            }
            if let Some(path) = self.cfg.run.checkpoint_path.clone() {
                if (every > 0 && t % every == 0) || t == outer_steps || hit {
                    if keep == 0 {
                        // retention off: one file, overwritten in place
                        self.snapshot(t).save(&path)?;
                        crate::debug!("checkpoint written to {path} at outer {t}");
                    } else {
                        // retention on (DESIGN.md §10): per-step files,
                        // pruned to the last N plus the merge-boundary
                        // checkpoints this run has seen
                        use crate::checkpoint::retention;
                        let file = retention::step_file(&path, t);
                        self.snapshot(t).save(&file)?;
                        let pinned: std::collections::BTreeSet<u64> =
                            self.recorder.merges.iter().map(|m| m.outer_step).collect();
                        let deleted = retention::enforce(&path, keep, &pinned)?;
                        crate::debug!(
                            "checkpoint written to {file} at outer {t} (pruned {} older)",
                            deleted.len()
                        );
                    }
                }
            }
            if let Some(ctl) = self.control.clone() {
                // service steering (DESIGN.md §13): every externally
                // requested mutation lands here, at the shared boundary
                // both schedulers cross — publish, park while paused,
                // snapshot, then cancel, in that order, so a pending
                // checkpoint is written even at the cancel boundary
                ctl.publish(BoundaryProgress {
                    outer_steps_done: t,
                    outer_steps_total: outer_steps,
                    live_instances: self.live_trainers(),
                    virtual_time_s: self.cluster.clock.max_time(),
                    total_samples: self.total_samples,
                });
                ctl.wait_while_paused();
                if let Some(path) = ctl.take_checkpoint_request() {
                    self.snapshot(t).save(&path)?;
                    crate::info!("service checkpoint written to {path} at outer {t}");
                    ctl.record_checkpoint(t, path);
                }
                if ctl.cancelled() {
                    crate::info!("service cancel honoured at outer boundary {t}");
                    break;
                }
            }
            if hit {
                crate::info!("target perplexity reached at outer step {t}; stopping");
                break;
            }
        }
        self.drain_overlap(last_t)?;
        self.accrue_vacant_all();
        self.record_utilization();
        self.run_wall_s = wall0.elapsed().as_secs_f64();
        self.recorder.wall_clock_s = self.run_wall_s;
        if let Some(ctl) = self.control.clone() {
            // final census for observers that poll after completion
            ctl.publish(BoundaryProgress {
                outer_steps_done: last_t,
                outer_steps_total: outer_steps,
                live_instances: self.live_trainers(),
                virtual_time_s: self.cluster.clock.max_time(),
                total_samples: self.total_samples,
            });
        }
        Ok(self.result())
    }

    /// Attach a service steering handle (DESIGN.md §13). Call before
    /// `run()`; with no handle attached the boundary hook is inert and
    /// the loop is byte-for-byte the one-shot path.
    pub fn set_boundary_control(&mut self, ctl: Arc<BoundaryControl>) {
        self.control = Some(ctl);
    }

    /// Attach a per-round step-record streaming sink writing toward
    /// `final_path` (`run.stream_records`). Call before `run()`.
    pub fn enable_record_streaming(&mut self, final_path: &str) -> Result<()> {
        self.streamer = Some(RecordStreamer::create(final_path)?);
        Ok(())
    }

    /// Finish the streaming sink: drain remaining steps and assemble the
    /// final JSONL (byte-identical to the buffered writer's). No-op when
    /// streaming was never enabled.
    pub fn finish_record_streaming(&mut self) -> Result<()> {
        if let Some(streamer) = self.streamer.take() {
            streamer.finish(&mut self.recorder)?;
        }
        Ok(())
    }

    /// Capture the full run state for checkpointing (the exact-resume
    /// contract: everything the remaining rounds read — parameters,
    /// optimizer state, every stochastic stream mid-sequence, sampler
    /// positions, controller statistics, time accounting, ledger
    /// counters and in-flight delayed syncs).
    pub fn snapshot(&self, outer_step: u64) -> crate::checkpoint::Checkpoint {
        use crate::checkpoint::{
            Checkpoint, PendingSnapshot, PhaseSnapshot, RegistryRowSnapshot, RngSnapshot,
            SamplerSnapshot, TrainerSnapshot, WorkerSnapshot,
        };
        use crate::comm::CommScope;
        let sampler_snap = |w: &crate::trainer::Worker| -> SamplerSnapshot {
            let st = w.sampler.export_state();
            SamplerSnapshot {
                shard: st.shard,
                order: st.order,
                cursor: st.cursor,
                drawn: st.drawn,
                rng: RngSnapshot { s: st.rng.0, gauss_spare: st.rng.1 },
            }
        };
        Checkpoint {
            config_name: self.cfg.name.clone(),
            config_digest: self.cfg.structural_digest(),
            outer_step,
            total_samples: self.total_samples,
            comm_count: self.comm.ledger.count() as u64,
            comm_bytes: self.comm.ledger.total_bytes(),
            comm_wan_bytes: self.comm.ledger.wan_bytes(),
            overlap_hidden_s: self.overlap_hidden_s,
            clock_times: (0..self.cluster.clock.len())
                .map(|w| self.cluster.clock.time(w))
                .collect(),
            busy_s: self.cluster.busy_s.clone(),
            wait_s: self.cluster.wait_s.clone(),
            comm_s: self.cluster.comm_s.clone(),
            comm_hidden_s: self.cluster.comm_hidden_s.clone(),
            preempted_s: self.cluster.preempted_s.clone(),
            vacant_s: self.cluster.vacant_s.clone(),
            spawn_count: self.registry.spawn_count,
            last_spawn_outer: self.registry.last_spawn_outer,
            last_merge_rep: self.registry.last_merge_rep,
            live_rounds_sum: self.live_rounds_sum,
            rounds_count: self.rounds_count,
            registry: self
                .registry
                .metas()
                .iter()
                .map(|m| RegistryRowSnapshot {
                    id: m.id.0,
                    state: m.state.as_str().to_string(),
                    origin: m.origin.as_str().to_string(),
                    born_outer: m.born_outer,
                    born_at_s: m.born_at_s,
                    retired_outer: m.retired_outer,
                    workers: self.trainers[m.id.0]
                        .workers
                        .iter()
                        .map(|w| (w.node, w.clock_slot))
                        .collect(),
                })
                .collect(),
            rng: RngSnapshot::of(&self.rng),
            trainers: self
                .trainers
                .iter()
                .filter(|t| t.alive)
                .map(|t| {
                    let ctrl = t.controller.export_state();
                    TrainerSnapshot {
                        id: t.id,
                        params: t.params.clone(),
                        outer_velocity: t.outer.velocity().to_vec(),
                        requested_batch: ctrl.requested,
                        inner_steps_done: t.inner_steps_done,
                        observations: ctrl.observations,
                        sigma2_ema: ctrl.sigma2_ema,
                        ip_var_ema: ctrl.ip_var_ema,
                        s1_ema: ctrl.s1_ema,
                        shard: t.shard.indices.clone(),
                        pending: self.pending_syncs[t.id].as_ref().map(|p| {
                            PendingSnapshot {
                                posted_at: p.handle.posted_at,
                                completes_at: p.handle.completes_at,
                                time_s: p.handle.cost.time_s,
                                sent_samples: p.sent_samples,
                                phases: p
                                    .handle
                                    .cost
                                    .phases
                                    .iter()
                                    .map(|ph| PhaseSnapshot {
                                        wan: ph.scope == CommScope::Wan,
                                        bytes: ph.bytes,
                                        participants: ph.participants,
                                    })
                                    .collect(),
                                delta: p.delta.clone(),
                            }
                        }),
                        workers: t
                            .workers
                            .iter()
                            .map(|w| WorkerSnapshot {
                                params: w.state.params.clone(),
                                m: w.state.m.clone(),
                                v: w.state.v.clone(),
                                step: w.state.step,
                                active: w.active,
                                noise_rng: RngSnapshot::of(&w.noise_rng),
                                time_rng: RngSnapshot::of(&w.time_rng),
                                sampler: sampler_snap(w),
                            })
                            .collect(),
                    }
                })
                .collect(),
        }
    }

    /// Restore the full run state from a checkpoint. Trainers present in
    /// the coordinator but absent from the checkpoint were merged away
    /// before the snapshot and are marked dead. The restore is exact:
    /// RNG streams, sampler positions, controller statistics, time
    /// accounting, ledger counters and in-flight delayed syncs all
    /// continue bit-for-bit (`tests/checkpoint_resume.rs`).
    pub fn restore(&mut self, cp: &crate::checkpoint::Checkpoint) -> Result<()> {
        use crate::batching::ControllerState;
        use crate::comm::{CommCost, CommPhase, CommScope};
        use crate::data::SamplerState;
        use crate::instances::{InstanceId, InstanceMeta, LifecycleState};
        use anyhow::{anyhow, ensure};
        let p = self.engine.param_count();

        // a nonzero digest identifies the structural config that wrote
        // the snapshot; exact resume under a different one would diverge
        // silently, so refuse it (0 = pre-v4 import, digest unknown)
        if cp.config_digest != 0 {
            ensure!(
                cp.config_digest == self.cfg.structural_digest(),
                "checkpoint was written by a different config (digest {:016x} != {:016x}); \
                 exact resume requires the same structural config — use a minimal \
                 (warm-start) checkpoint to transfer parameters across configs",
                cp.config_digest,
                self.cfg.structural_digest()
            );
        }

        // ---- elastic pool structure (DESIGN.md §9): rebuild instances
        //      that did not exist at config time — live ones as shells
        //      the state restore below fills, retired ones as frozen
        //      placeholders so ids, slots and utilization rows all
        //      reproduce the uninterrupted run ----------------------------
        while self.cluster.clock.len() < cp.clock_times.len() {
            self.cluster.push_slot(0.0);
        }
        let initial = self.trainers.len();
        for row in &cp.registry {
            if row.id < initial {
                continue;
            }
            ensure!(
                row.id == self.trainers.len(),
                "checkpoint registry rows out of order at id {}",
                row.id
            );
            ensure!(!row.workers.is_empty(), "registry row {} has no workers", row.id);
            for &(node, slot) in &row.workers {
                ensure!(
                    node < self.cluster.nodes.len(),
                    "registry row {} node {node} out of range",
                    row.id
                );
                while self.cluster.clock.len() <= slot {
                    self.cluster.push_slot(0.0);
                }
            }
            let slots: Vec<usize> = row.workers.iter().map(|&(_, s)| s).collect();
            let node = row.workers[0].0;
            // shell only: params/streams/samplers of live instances are
            // overwritten by the snapshot restore below; retired ones
            // are never touched again
            let mut shell_rng = Rng::new(0);
            let mut tr = Trainer::spawned(
                row.id,
                vec![0.0; p],
                &self.cfg.algo,
                crate::data::Shard { indices: Vec::new() },
                node,
                &slots,
                &mut shell_rng,
            );
            for (w, &(n, s)) in tr.workers.iter_mut().zip(row.workers.iter()) {
                w.node = n;
                w.clock_slot = s;
            }
            self.trainers.push(tr);
            self.pending_syncs.push(None);
        }
        // rebuild the registry rows + spawn bookkeeping
        for row in &cp.registry {
            self.registry.restore_row(InstanceMeta {
                id: InstanceId(row.id),
                state: LifecycleState::parse(&row.state)
                    .ok_or_else(|| anyhow!("bad registry state {:?}", row.state))?,
                born_outer: row.born_outer,
                born_at_s: row.born_at_s,
                retired_outer: row.retired_outer,
                origin: crate::instances::Origin::parse(&row.origin)
                    .ok_or_else(|| anyhow!("bad registry origin {:?}", row.origin))?,
            })?;
        }
        self.registry.spawn_count = cp.spawn_count;
        self.registry.last_spawn_outer = cp.last_spawn_outer;
        self.registry.last_merge_rep = cp.last_merge_rep;
        self.live_rounds_sum = cp.live_rounds_sum;
        self.rounds_count = cp.rounds_count;

        for t in &mut self.trainers {
            t.alive = false;
        }
        for snap in &cp.trainers {
            ensure!(
                snap.id < self.trainers.len(),
                "checkpoint trainer id {} out of range (config has {})",
                snap.id,
                self.trainers.len()
            );
            ensure!(
                snap.params.len() == p,
                "checkpoint param count {} != engine {}",
                snap.params.len(),
                p
            );
            let t = &mut self.trainers[snap.id];
            ensure!(
                snap.workers.len() == t.workers.len(),
                "checkpoint worker count mismatch for trainer {}",
                snap.id
            );
            t.alive = true;
            t.params.copy_from_slice(&snap.params);
            t.outer.set_velocity(&snap.outer_velocity);
            t.controller.restore_state(&ControllerState {
                requested: snap.requested_batch,
                observations: snap.observations,
                sigma2_ema: snap.sigma2_ema,
                ip_var_ema: snap.ip_var_ema,
                s1_ema: snap.s1_ema,
            });
            t.inner_steps_done = snap.inner_steps_done;
            t.shard = crate::data::Shard { indices: snap.shard.clone() };
            for (w, ws) in t.workers.iter_mut().zip(snap.workers.iter()) {
                w.state.params.copy_from_slice(&ws.params);
                w.state.m.copy_from_slice(&ws.m);
                w.state.v.copy_from_slice(&ws.v);
                w.state.step = ws.step;
                w.active = ws.active;
                w.noise_rng = ws.noise_rng.to_rng();
                w.time_rng = ws.time_rng.to_rng();
                w.sampler = crate::data::BatchSampler::from_state(SamplerState {
                    shard: ws.sampler.shard.clone(),
                    order: ws.sampler.order.clone(),
                    cursor: ws.sampler.cursor,
                    drawn: ws.sampler.drawn,
                    rng: (ws.sampler.rng.s, ws.sampler.rng.gauss_spare),
                });
            }
            // re-arm any delayed collective that was in flight
            let pending = match &snap.pending {
                None => None,
                Some(pj) => {
                    let handle = SyncHandle {
                        kind: CommKind::OuterSync,
                        cost: CommCost {
                            time_s: pj.time_s,
                            phases: pj
                                .phases
                                .iter()
                                .map(|ph| CommPhase {
                                    scope: if ph.wan {
                                        CommScope::Wan
                                    } else {
                                        CommScope::Intra
                                    },
                                    bytes: ph.bytes,
                                    participants: ph.participants,
                                })
                                .collect(),
                        },
                        posted_at: pj.posted_at,
                        completes_at: pj.completes_at,
                    };
                    self.comm.adopt_in_flight(&handle);
                    Some(PendingSync {
                        handle,
                        delta: pj.delta.clone(),
                        sent_samples: pj.sent_samples,
                    })
                }
            };
            self.pending_syncs[snap.id] = pending;
        }
        for (w, &t) in cp.clock_times.iter().enumerate().map(|(i, t)| (i, t)) {
            if w < self.cluster.clock.len() {
                let cur = self.cluster.clock.time(w);
                if t > cur {
                    self.cluster.clock.advance(w, t - cur);
                }
            }
        }
        // per-slot time accounting continues the saved f64 sequences
        let slots = self.cluster.busy_s.len();
        for (dst, src) in [
            (&mut self.cluster.busy_s, &cp.busy_s),
            (&mut self.cluster.wait_s, &cp.wait_s),
            (&mut self.cluster.comm_s, &cp.comm_s),
            (&mut self.cluster.comm_hidden_s, &cp.comm_hidden_s),
            (&mut self.cluster.preempted_s, &cp.preempted_s),
            (&mut self.cluster.vacant_s, &cp.vacant_s),
        ] {
            for (w, &v) in src.iter().enumerate().take(slots) {
                dst[w] = v;
            }
        }
        self.rng = cp.rng.to_rng();
        self.overlap_hidden_s = cp.overlap_hidden_s;
        self.comm.ledger.resume_from(
            cp.comm_count as usize,
            cp.comm_bytes,
            cp.comm_wan_bytes,
        );
        self.total_samples = cp.total_samples;
        Ok(())
    }

    /// Warm-start from a minimal (params + RNG) interchange: copy each
    /// snapshot trainer's outer parameters into the trainer and all of
    /// its workers, restore the worker noise/time streams and the
    /// coordinator stream, and leave everything else — optimizer
    /// moments, samplers, controller statistics, accounting, the
    /// schedule itself — at its fresh-run state. Unlike exact resume, a
    /// config-digest mismatch only warns: transferring trained
    /// parameters into a different setup is the point of the minimal
    /// variant (DESIGN.md §10).
    pub fn warm_start(&mut self, m: &crate::checkpoint::MinimalCheckpoint) -> Result<()> {
        use anyhow::ensure;
        let p = self.engine.param_count();
        if m.config_digest != 0 && m.config_digest != self.cfg.structural_digest() {
            crate::warn!(
                "minimal checkpoint comes from a different config \
                 (digest {:016x} != {:016x}); warm-starting anyway",
                m.config_digest,
                self.cfg.structural_digest()
            );
        }
        for snap in &m.trainers {
            ensure!(
                snap.id < self.trainers.len(),
                "minimal checkpoint trainer id {} out of range (config has {})",
                snap.id,
                self.trainers.len()
            );
            ensure!(
                snap.params.len() == p,
                "minimal checkpoint param count {} != engine {}",
                snap.params.len(),
                p
            );
            let t = &mut self.trainers[snap.id];
            t.params.copy_from_slice(&snap.params);
            for w in t.workers.iter_mut() {
                w.state.params.copy_from_slice(&snap.params);
            }
            for (w, ws) in t.workers.iter_mut().zip(snap.workers.iter()) {
                w.noise_rng = ws.noise_rng.to_rng();
                w.time_rng = ws.time_rng.to_rng();
            }
        }
        self.rng = m.rng.to_rng();
        Ok(())
    }

    // ------------------------------------------------------------------
    // shared building blocks (both schedulers)
    // ------------------------------------------------------------------

    /// The step plan this trainer uses for the whole outer step
    /// (Algorithm 3 lines 17-27 — b_req was stored at the previous one).
    fn plan_for(&self, ti: usize) -> StepPlan {
        let tr = &self.trainers[ti];
        let a = &self.cfg.algo;
        let b_req = if a.batching.adaptive { tr.requested_batch() } else { a.fixed_batch };
        let max_batch = self.max_batch_for(tr);
        plan_step(
            b_req,
            max_batch,
            a.switch.multiplier,
            a.switch.enabled,
            self.engine.supported_batches(),
        )
    }

    /// The engine work of one inner step of worker `wi` of trainer `ti`
    /// over the coordinator's shared scratch buffers — a thin borrow
    /// adapter around the shared [`exec_step`] (which the parallel
    /// chains call with chain-local scratch).
    fn exec_worker_step(
        &mut self,
        ti: usize,
        wi: usize,
        plan: &StepPlan,
        lr: f64,
    ) -> Result<StepStats> {
        let width = self.corpus.width();
        let bi = self.batch_buf_for(plan.micro_batch, width);
        exec_step(
            self.engine.as_ref(),
            &self.corpus,
            &mut self.trainers[ti].workers[wi],
            plan,
            lr,
            StepScratch {
                buf: &mut self.batch_bufs[bi],
                grad: &mut self.grad_scratch,
                accum: &mut self.accum_scratch,
            },
        )
    }

    /// Index of the reusable token buffer for this (batch, width),
    /// creating it on first use. The set of sizes is bounded by the
    /// engine's batch ladder, so the cache stays tiny.
    fn batch_buf_for(&mut self, batch: usize, width: usize) -> usize {
        match self
            .batch_bufs
            .iter()
            .position(|b| b.batch == batch && b.width == width)
        {
            Some(i) => i,
            None => {
                self.batch_bufs.push(TokenBatch::new(batch, width));
                self.batch_bufs.len() - 1
            }
        }
    }

    /// Compute-time of one inner step of worker `wi` — a borrow adapter
    /// around the shared [`step_compute_time`] (used by both schedulers;
    /// the parallel chains call it directly).
    fn step_duration(&mut self, ti: usize, wi: usize, plan: &StepPlan) -> f64 {
        let width = self.corpus.width();
        let jitter = self.cfg.cluster.step_jitter;
        let w = &mut self.trainers[ti].workers[wi];
        step_compute_time(&self.cluster.nodes[w.node], plan, width, jitter, &mut w.time_rng)
    }

    /// True when the run uses ACCO-style delayed outer syncs
    /// (DESIGN.md §8): collectives post non-blocking and outer updates
    /// apply one round late.
    pub(crate) fn overlap_delayed(&self) -> bool {
        self.cfg.comm.overlap == OverlapMode::Delayed
    }

    /// The delayed-overlap outer boundary of trainer `ti`
    /// (DESIGN.md §8), shared verbatim by the lockstep walk and the
    /// event scheduler so the two stay bit-identical on static clusters:
    ///
    /// 1. freeze this round's delta over the active workers (the next
    ///    broadcast overwrites their buffers),
    /// 2. post the collective non-blocking at the cohort front `t_send`
    ///    (the completion can't precede the last contribution),
    /// 3. apply the *previous* round's update, stalling only for the
    ///    part of its transfer this round's compute did not hide.
    pub(crate) fn outer_sync_delayed(
        &mut self,
        ti: usize,
        slots: &[usize],
        member_nodes: &[usize],
        bw_factor: f64,
    ) {
        let param_bytes = (self.engine.param_count() * 4) as u64;
        let t_send = slots
            .iter()
            .map(|&s| self.cluster.clock.time(s))
            .fold(0.0_f64, f64::max);
        let cost =
            self.comm
                .sync_cost(param_bytes, member_nodes, &self.cluster.topology, bw_factor);
        // recycled delta buffer (DESIGN.md §14): clear+resize re-zeroes
        // the span, bit-identical to the fresh `vec![0.0f32; p]` this
        // used to allocate every delayed boundary
        let mut delta = self.delta_pool.pop().unwrap_or_default();
        delta.clear();
        delta.resize(self.engine.param_count(), 0.0);
        if !self.trainers[ti].active_delta(&mut delta) {
            // fully-preempted cohort: nothing to post this round (the
            // blocking epilogue is the same no-op); any older pending
            // update keeps waiting for the next live boundary
            self.delta_pool.push(delta);
            return;
        }
        let handle = self.comm.begin_sync(CommKind::OuterSync, cost, t_send);
        let prev = self.pending_syncs[ti].replace(PendingSync {
            handle,
            delta,
            sent_samples: self.total_samples,
        });
        match prev {
            Some(prev) => self.apply_pending(ti, slots, prev),
            // first boundary: nothing to apply yet, but the cohort still
            // aligns (zero comm) before the next broadcast
            None => {
                self.cluster.barrier_tracked(slots, 0.0);
            }
        }
    }

    /// Apply a delayed update at the current cohort front: barrier the
    /// members charging only the *exposed* residue of the transfer as
    /// comm time, credit the hidden part, land the ledger rows at the
    /// completion timestamp captured at post, and step the outer
    /// optimizer along the (one-round-stale) delta — Nesterov velocity
    /// continues in application order across the delay.
    fn apply_pending(&mut self, ti: usize, slots: &[usize], prev: PendingSync) {
        let t_start = slots
            .iter()
            .map(|&s| self.cluster.clock.time(s))
            .fold(0.0_f64, f64::max);
        let exposed = (prev.handle.completes_at - t_start).max(0.0);
        self.cluster.barrier_tracked(slots, exposed);
        // hidden = min(transfer, time since post) — the cohort front can
        // never sit before the post point, so this is non-negative; the
        // max(0.0) only guards float dust
        let hidden = (prev.handle.cost.time_s - exposed).max(0.0);
        self.cluster.charge_hidden(slots, hidden);
        self.overlap_hidden_s += hidden;
        self.comm.complete_sync(&prev.handle, prev.sent_samples);
        let tr = &mut self.trainers[ti];
        tr.outer.step(&mut tr.params, &prev.delta);
        // recycle the delta buffer for the next delayed post
        self.delta_pool.push(prev.delta);
    }

    /// Retire trainer `ti`'s in-flight update immediately (merge
    /// rendezvous and end-of-run drains): the cohort waits out whatever
    /// part of the transfer has not completed, then the update applies.
    pub(crate) fn drain_pending(&mut self, ti: usize) {
        let Some(prev) = self.pending_syncs[ti].take() else { return };
        let mut slots: Vec<usize> = self.trainers[ti]
            .workers
            .iter()
            .filter(|w| w.active)
            .map(|w| w.clock_slot)
            .collect();
        if slots.is_empty() {
            // fully-preempted cohort: fall back to the frozen clocks,
            // like the merge rendezvous does
            slots =
                self.trainers[ti].workers.iter().map(|w| w.clock_slot).collect();
        }
        self.apply_pending(ti, &slots, prev);
    }

    /// End-of-run drain of the delayed-overlap mode (DESIGN.md §8):
    /// every live trainer's final update applies (fully exposed — there
    /// is no next round to hide it under), then one last evaluation
    /// records the fully-applied parameters.
    fn drain_overlap(&mut self, outer_t: u64) -> Result<()> {
        if !self.overlap_delayed() {
            return Ok(());
        }
        let live: Vec<usize> = (0..self.trainers.len())
            .filter(|&i| self.trainers[i].alive)
            .collect();
        let mut drained = false;
        for &ti in &live {
            if self.pending_syncs[ti].is_some() {
                self.drain_pending(ti);
                drained = true;
            }
        }
        if drained {
            for &ti in &live {
                self.evaluate_trainer_params(ti, outer_t)?;
            }
        }
        Ok(())
    }

    /// Validation loss/perplexity of `params` (fresh per-call eval RNG
    /// keyed by the outer step, so the draw is independent of when or in
    /// which order evaluations execute).
    fn compute_eval(&mut self, params: &[f32], outer_t: u64) -> Result<(f64, f64)> {
        let eb = self.engine.eval_batch();
        let width = self.val_corpus.width();
        let mut eval_rng = Rng::new(self.cfg.seed ^ 0xE7A1 ^ outer_t);
        let mut loss_acc = 0.0;
        let n = self.cfg.run.eval_batches.max(1);
        // reuse the shared (batch, width) buffer cache instead of a
        // fresh TokenBatch per evaluation; every row is overwritten
        // below before the engine reads it
        let bi = self.batch_buf_for(eb, width);
        for _ in 0..n {
            for row in 0..eb {
                let ix = eval_rng.below(self.val_corpus.len() as u64) as usize;
                self.batch_bufs[bi].row_mut(row).copy_from_slice(self.val_corpus.sequence(ix));
            }
            loss_acc += self.engine.eval_loss(params, &self.batch_bufs[bi], &mut eval_rng)?;
        }
        let loss = loss_acc / n as f64;
        Ok((loss, perplexity(loss)))
    }

    fn eval_params(&mut self, params: &[f32], ti: usize, outer_t: u64) -> Result<bool> {
        let (loss, ppl) = self.compute_eval(params, outer_t)?;
        let tr = &self.trainers[ti];
        let vt = tr
            .workers
            .iter()
            .map(|w| self.cluster.clock.time(w.clock_slot))
            .fold(0.0f64, f64::max);
        self.recorder.evals.push(EvalRecord {
            global_step: tr.inner_steps_done,
            outer_step: outer_t,
            trainer: ti,
            loss,
            perplexity: ppl,
            virtual_time_s: vt,
            comm_count: self.comm.ledger.count(),
            comm_bytes: self.comm.ledger.total_bytes(),
        });
        Ok(self.cfg.run.target_ppl > 0.0 && ppl <= self.cfg.run.target_ppl)
    }

    /// Evaluate worker-0 parameters of trainer `ti` (mid-outer-step eval,
    /// the paper's every-10-steps cadence). Returns true if target reached.
    fn evaluate(&mut self, ti: usize, outer_t: u64) -> Result<bool> {
        // stage into the reusable eval buffer instead of cloning a
        // fresh param vector per evaluation (DESIGN.md §14)
        let mut params = std::mem::take(&mut self.eval_scratch);
        params.clear();
        params.extend_from_slice(&self.trainers[ti].workers[0].state.params);
        let out = self.eval_params(&params, ti, outer_t);
        self.eval_scratch = params;
        out
    }

    /// Evaluate the trainer's outer parameters (post-sync).
    fn evaluate_trainer_params(&mut self, ti: usize, outer_t: u64) -> Result<bool> {
        let mut params = std::mem::take(&mut self.eval_scratch);
        params.clear();
        params.extend_from_slice(&self.trainers[ti].params);
        let out = self.eval_params(&params, ti, outer_t);
        self.eval_scratch = params;
        out
    }

    /// Fill the recorder's per-worker utilization table.
    fn record_utilization(&mut self) {
        self.recorder.utilization = self.cluster.utilization_table(&self.trainers);
    }

    /// Final summary.
    pub fn result(&self) -> RunResult {
        let utils = self.cluster.utilization_table(&self.trainers);
        let total_idle_s: f64 = utils.iter().map(|u| u.idle_s()).sum();
        let total_vacant_s: f64 = utils.iter().map(|u| u.vacant_s).sum();
        let mean_utilization = if utils.is_empty() {
            0.0
        } else {
            utils.iter().map(|u| u.utilization()).sum::<f64>() / utils.len() as f64
        };
        RunResult {
            name: self.cfg.name.clone(),
            method: self.cfg.algo.method,
            best_ppl: self.recorder.best_perplexity().unwrap_or(f64::INFINITY),
            final_ppl: self.recorder.final_perplexity().unwrap_or(f64::INFINITY),
            total_inner_steps: self
                .trainers
                .iter()
                .map(|t| t.inner_steps_done)
                .max()
                .unwrap_or(0),
            total_samples: self.total_samples,
            comm_count: self.comm.ledger.count(),
            comm_bytes: self.comm.ledger.total_bytes(),
            wan_comm_bytes: self.comm.ledger.wan_bytes(),
            virtual_time_s: self.cluster.clock.max_time(),
            trainers_left: self.live_trainers(),
            total_idle_s,
            mean_utilization,
            time_to_target: if self.cfg.run.target_ppl > 0.0 {
                self.recorder.time_to_target(self.cfg.run.target_ppl)
            } else {
                None
            },
            overlap_hidden_s: self.overlap_hidden_s,
            spawn_count: self.registry.spawn_count,
            mean_live_instances: if self.rounds_count > 0 {
                self.live_rounds_sum as f64 / self.rounds_count as f64
            } else {
                self.live_trainers() as f64
            },
            total_vacant_s,
            wall_clock_s: self.run_wall_s,
            threads: self.threads,
        }
    }
}

/// Convenience: build engine + coordinator from a config and run it.
pub fn run_experiment(cfg: Config) -> Result<RunResult> {
    let engine = crate::engine::build_engine(&cfg)?;
    let mut coord = Coordinator::new(cfg, engine)?;
    let stream = coord.cfg.run.stream_records;
    let base = coord.cfg.out_dir.clone().map(|dir| format!("{dir}/{}", coord.cfg.name));
    if stream {
        if let Some(base) = &base {
            coord.enable_record_streaming(&format!("{base}.jsonl"))?;
        }
        // stream_records without out_dir degrades to buffered (nothing
        // would be written anyway)
    }
    let result = coord.run()?;
    if let Some(base) = base {
        if stream {
            coord.finish_record_streaming()?;
        } else {
            coord.recorder.write_jsonl(&format!("{base}.jsonl"))?;
        }
        coord.recorder.write_eval_csv(&format!("{base}.csv"))?;
    }
    Ok(result)
}
