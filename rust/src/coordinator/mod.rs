//! The AdLoCo coordinator (paper Algorithm 3): the run loop that composes
//! adaptive batching, SwitchMode accumulation, multi-instance merging and
//! DiLoCo-style outer optimization over a simulated cluster.
//!
//! The same loop realizes every method and ablation arm in the paper via
//! the config knobs (see [`resolve_policy`]):
//!
//! | run                    | adaptive | merge | switch | outer opt |
//! |------------------------|----------|-------|--------|-----------|
//! | AdLoCo (full)          | on       | on    | on     | Nesterov  |
//! | DiLoCo baseline        | off      | off   | off    | Nesterov  |
//! | LocalSGD baseline      | off      | off   | off    | Average   |
//! | Fig. 2 −adaptive       | off      | on    | on     | Nesterov  |
//! | Fig. 2 −merge          | on       | off   | on     | Nesterov  |
//! | Fig. 2 −switch         | on       | on    | off    | Nesterov  |
//!
//! Timekeeping is virtual (DESIGN.md §3): compute advances each worker's
//! clock through the node's step-time model; outer syncs and merges are
//! barriers plus modeled all-reduce/transfer time; the ledger records
//! every communication for the C(N) analyses (Theorem 2).

use crate::batching::{plan_step, StepPlan};
use crate::config::{Config, Method};
use crate::data::{make_shards, shard::union_shards, Corpus, CorpusSpec, TokenBatch};
use crate::engine::{StepStats, TrainEngine};
use crate::merge::{check_merge_with_policy, do_merge, MergePolicy};
use crate::metrics::{perplexity, EvalRecord, MergeRecord, Recorder, StepRecord};
use crate::simulator::{
    assign_workers, node_models, CommEvent, CommKind, CommLedger, NetworkModel, NodeModel,
    VirtualClock,
};
use crate::trainer::Trainer;
use crate::util::Rng;
use anyhow::Result;

/// Outcome summary of a run (full series live in the recorder).
#[derive(Clone, Debug)]
pub struct RunResult {
    pub name: String,
    pub method: Method,
    /// Best validation perplexity seen by any live trainer.
    pub best_ppl: f64,
    pub final_ppl: f64,
    pub total_inner_steps: u64,
    pub total_samples: u64,
    pub comm_count: usize,
    pub comm_bytes: u64,
    pub virtual_time_s: f64,
    pub trainers_left: usize,
    /// (step, time, comms) at which target_ppl was first reached, if ever.
    pub time_to_target: Option<(u64, f64, usize)>,
}

/// Apply the method's policy constraints to a copy of the config
/// (DiLoCo = AdLoCo minus adaptivity/merging/switching; LocalSGD further
/// degrades the outer optimizer to plain averaging — §3.1, §3.2).
pub fn resolve_policy(cfg: &Config) -> Config {
    let mut out = cfg.clone();
    match cfg.algo.method {
        Method::AdLoCo => {}
        Method::DiLoCo => {
            out.algo.batching.adaptive = false;
            out.algo.merge.enabled = false;
            out.algo.switch.enabled = false;
        }
        Method::LocalSgd => {
            out.algo.batching.adaptive = false;
            out.algo.merge.enabled = false;
            out.algo.switch.enabled = false;
            out.algo.outer_opt = crate::config::OuterOptKind::Average;
        }
    }
    out
}

pub struct Coordinator {
    cfg: Config,
    engine: Box<dyn TrainEngine>,
    corpus: Corpus,
    val_corpus: Corpus,
    trainers: Vec<Trainer>,
    clock: VirtualClock,
    nodes: Vec<NodeModel>,
    net: NetworkModel,
    ledger: CommLedger,
    pub recorder: Recorder,
    rng: Rng,
    /// Reusable buffers (hot path: no allocation per step).
    delta_scratch: Vec<f32>,
    grad_scratch: Vec<f32>,
    accum_scratch: Vec<f32>,
    batch_buf: TokenBatch,
    /// Samples consumed across the run (the N axis of Theorem 2).
    total_samples: u64,
    /// Inner-lr schedule (evaluated on each trainer's inner-step count).
    lr_schedule: crate::schedule::Schedule,
}

impl Coordinator {
    /// Build a coordinator (generates data, shards it, places workers).
    pub fn new(cfg: Config, engine: Box<dyn TrainEngine>) -> Result<Coordinator> {
        let cfg = resolve_policy(&cfg);
        cfg.validate()?;
        let a = &cfg.algo;

        let seq_width_minus1 = cfg.data.seq_len;
        let corpus = Corpus::generate(CorpusSpec::new(
            cfg.data.corpus_sequences,
            seq_width_minus1,
            cfg.data.vocab,
            cfg.data.zipf_s,
            cfg.data.seed,
        ));
        let val_corpus = Corpus::generate(CorpusSpec::new(
            cfg.data.val_sequences.max(engine.eval_batch()),
            seq_width_minus1,
            cfg.data.vocab,
            cfg.data.zipf_s,
            cfg.data.seed ^ 0xFACE,
        ));

        let mut rng = Rng::new(cfg.seed);
        let k = a.num_trainers;
        let m = a.workers_per_trainer;
        let shards = make_shards(corpus.len(), k, cfg.data.shard_fraction, &mut rng);
        let placement = assign_workers(k * m, cfg.cluster.nodes.len());

        let mut trainers = Vec::with_capacity(k);
        for (i, shard) in shards.into_iter().enumerate() {
            let nodes_of_workers: Vec<usize> =
                (0..m).map(|j| placement[i * m + j]).collect();
            trainers.push(Trainer::new(
                i,
                engine.as_ref(),
                a,
                shard,
                &nodes_of_workers,
                i * m,
                // trainer 0 uses the canonical init; others are
                // independent initializations (MIT §4.1)
                i as u64,
                &mut rng,
            ));
        }

        let p = engine.param_count();
        let width = cfg.data.seq_len + 1;
        let mut recorder = Recorder::new();
        recorder.note("engine", engine.name());
        recorder.note("method", a.method.as_str());
        recorder.note("config", cfg.name.clone());

        Ok(Coordinator {
            clock: VirtualClock::new(k * m),
            nodes: node_models(&cfg.cluster),
            net: NetworkModel {
                latency_s: cfg.cluster.net_latency_s,
                bandwidth_bps: cfg.cluster.net_bandwidth_bps,
            },
            ledger: CommLedger::default(),
            recorder,
            rng,
            delta_scratch: vec![0.0; p],
            grad_scratch: vec![0.0; p],
            accum_scratch: vec![0.0; p],
            batch_buf: TokenBatch::new(1, width),
            total_samples: 0,
            lr_schedule: crate::schedule::Schedule::from_config(
                &cfg.algo.lr_schedule,
                (cfg.algo.outer_steps * cfg.algo.inner_steps) as u64,
            ),
            cfg,
            engine,
            corpus,
            val_corpus,
            trainers,
        })
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    pub fn ledger(&self) -> &CommLedger {
        &self.ledger
    }

    pub fn live_trainers(&self) -> usize {
        self.trainers.iter().filter(|t| t.alive).count()
    }

    /// The effective hardware max_batch for a trainer: the smallest node
    /// budget among its workers, capped by the engine's compiled ladder.
    fn max_batch_for(&self, t: &Trainer) -> usize {
        let node_min = t
            .workers
            .iter()
            .map(|w| self.nodes[w.node].max_batch)
            .min()
            .unwrap_or(1);
        node_min.min(self.engine.max_batch()).max(1)
    }

    /// Run the full schedule (T outer steps of H inner steps), honouring
    /// the checkpoint/resume settings in `run` config.
    pub fn run(&mut self) -> Result<RunResult> {
        let mut start = 1u64;
        if let Some(path) = self.cfg.run.resume_from.clone() {
            let cp = crate::checkpoint::Checkpoint::load(&path)?;
            start = cp.outer_step + 1;
            self.restore(&cp)?;
            crate::info!("resumed from {path} at outer step {}", cp.outer_step);
        }
        let outer_steps = self.cfg.algo.outer_steps as u64;
        let every = self.cfg.run.checkpoint_every as u64;
        for t in start..=outer_steps {
            let hit = self.step_outer(t)?;
            if let Some(path) = self.cfg.run.checkpoint_path.clone() {
                if (every > 0 && t % every == 0) || t == outer_steps || hit {
                    self.snapshot(t).save(&path)?;
                    crate::debug!("checkpoint written to {path} at outer {t}");
                }
            }
            if hit {
                crate::info!("target perplexity reached at outer step {t}; stopping");
                break;
            }
        }
        Ok(self.result())
    }

    /// Capture the trainer pool for checkpointing.
    pub fn snapshot(&self, outer_step: u64) -> crate::checkpoint::Checkpoint {
        use crate::checkpoint::{Checkpoint, TrainerSnapshot, WorkerSnapshot};
        Checkpoint {
            config_name: self.cfg.name.clone(),
            outer_step,
            total_samples: self.total_samples,
            comm_count: self.ledger.count() as u64,
            comm_bytes: self.ledger.total_bytes(),
            clock_times: (0..self.clock.len()).map(|w| self.clock.time(w)).collect(),
            trainers: self
                .trainers
                .iter()
                .filter(|t| t.alive)
                .map(|t| TrainerSnapshot {
                    id: t.id,
                    params: t.params.clone(),
                    outer_velocity: t.outer.velocity().to_vec(),
                    requested_batch: t.controller.requested(),
                    inner_steps_done: t.inner_steps_done,
                    workers: t
                        .workers
                        .iter()
                        .map(|w| WorkerSnapshot {
                            params: w.state.params.clone(),
                            m: w.state.m.clone(),
                            v: w.state.v.clone(),
                            step: w.state.step,
                        })
                        .collect(),
                })
                .collect(),
        }
    }

    /// Restore trainer state from a checkpoint. Trainers present in the
    /// coordinator but absent from the checkpoint were merged away before
    /// the snapshot and are marked dead. Data-pipeline position restarts
    /// from the config seed (see checkpoint module docs).
    pub fn restore(&mut self, cp: &crate::checkpoint::Checkpoint) -> Result<()> {
        use anyhow::ensure;
        let p = self.engine.param_count();
        for t in &mut self.trainers {
            t.alive = false;
        }
        for snap in &cp.trainers {
            ensure!(
                snap.id < self.trainers.len(),
                "checkpoint trainer id {} out of range (config has {})",
                snap.id,
                self.trainers.len()
            );
            ensure!(
                snap.params.len() == p,
                "checkpoint param count {} != engine {}",
                snap.params.len(),
                p
            );
            let t = &mut self.trainers[snap.id];
            ensure!(
                snap.workers.len() == t.workers.len(),
                "checkpoint worker count mismatch for trainer {}",
                snap.id
            );
            t.alive = true;
            t.params.copy_from_slice(&snap.params);
            t.outer.set_velocity(&snap.outer_velocity);
            t.controller.set_requested(snap.requested_batch);
            t.inner_steps_done = snap.inner_steps_done;
            for (w, ws) in t.workers.iter_mut().zip(snap.workers.iter()) {
                w.state.params.copy_from_slice(&ws.params);
                w.state.m.copy_from_slice(&ws.m);
                w.state.v.copy_from_slice(&ws.v);
                w.state.step = ws.step;
            }
        }
        for (w, &t) in cp.clock_times.iter().enumerate().map(|(i, t)| (i, t)) {
            if w < self.clock.len() {
                let cur = self.clock.time(w);
                if t > cur {
                    self.clock.advance(w, t - cur);
                }
            }
        }
        self.total_samples = cp.total_samples;
        Ok(())
    }

    /// One outer step. Returns true if the target perplexity was reached.
    pub fn step_outer(&mut self, outer_t: u64) -> Result<bool> {
        // ---- merging (Algorithm 3 lines 11-16) -------------------------
        let mc = self.cfg.algo.merge.clone();
        if mc.enabled
            && self.live_trainers() > 1
            && mc.frequency > 0
            && outer_t % mc.frequency as u64 == 0
        {
            self.maybe_merge(outer_t)?;
        }

        // ---- inner loops ------------------------------------------------
        let h = self.cfg.algo.inner_steps;
        let live: Vec<usize> = (0..self.trainers.len())
            .filter(|&i| self.trainers[i].alive)
            .collect();
        let mut hit_target = false;

        for &ti in &live {
            self.trainers[ti].broadcast_params();
            let plan = self.plan_for(ti);
            for step_h in 1..=h {
                self.inner_step(ti, outer_t, &plan)?;
                // cap on total inner steps (profiling / quick runs)
                let cap = self.cfg.run.max_inner_steps as u64;
                if cap > 0 && self.trainers[ti].inner_steps_done >= cap {
                    break;
                }
                // periodic evaluation on worker-0's live parameters
                if self.cfg.run.eval_every > 0
                    && step_h % self.cfg.run.eval_every == 0
                {
                    let reached = self.evaluate(ti, outer_t)?;
                    hit_target |= reached;
                }
            }
        }

        // ---- outer sync (Algorithm 3 lines 40-44) ------------------------
        let param_bytes = (self.engine.param_count() * 4) as u64;
        for &ti in &live {
            let m = self.trainers[ti].workers.len();
            let slots: Vec<usize> =
                self.trainers[ti].workers.iter().map(|w| w.clock_slot).collect();
            let comm_t = self.net.allreduce_time(param_bytes, m);
            let t_after = self.clock.barrier(&slots, comm_t);
            if m > 1 {
                self.ledger.record(CommEvent {
                    kind: CommKind::OuterSync,
                    at_virtual_s: t_after,
                    bytes: (2 * (m as u64 - 1)) * param_bytes,
                    participants: m,
                    at_inner_step: self.total_samples, // N axis: samples
                });
            }
            let tr = &mut self.trainers[ti];
            tr.outer_step(&mut self.delta_scratch);
        }

        // end-of-outer-step evaluation on the trainer parameters
        for &ti in &live {
            if self.trainers[ti].alive {
                let reached = self.evaluate_trainer_params(ti, outer_t)?;
                hit_target |= reached;
            }
        }
        Ok(hit_target)
    }

    /// The step plan this trainer uses for the whole outer step
    /// (Algorithm 3 lines 17-27 — b_req was stored at the previous one).
    fn plan_for(&self, ti: usize) -> StepPlan {
        let tr = &self.trainers[ti];
        let a = &self.cfg.algo;
        let b_req = if a.batching.adaptive { tr.requested_batch() } else { a.fixed_batch };
        let max_batch = self.max_batch_for(tr);
        plan_step(
            b_req,
            max_batch,
            a.switch.multiplier,
            a.switch.enabled,
            self.engine.supported_batches(),
        )
    }

    /// One inner step of every worker of trainer `ti`.
    fn inner_step(&mut self, ti: usize, outer_t: u64, plan: &StepPlan) -> Result<()> {
        let lr = self
            .lr_schedule
            .lr(self.cfg.algo.lr_inner, self.trainers[ti].inner_steps_done + 1);
        let n_workers = self.trainers[ti].workers.len();
        let width = self.corpus.width();

        for wi in 0..n_workers {
            // (re)size the shared batch buffer for this plan
            if self.batch_buf.batch != plan.micro_batch || self.batch_buf.width != width {
                self.batch_buf = TokenBatch::new(plan.micro_batch, width);
            }

            let stats = if plan.accum_steps > 1 {
                // SwitchMode: accumulate accum_steps gradients at the
                // micro batch, then one optimizer commit (§4.2).
                self.accum_scratch.iter_mut().for_each(|x| *x = 0.0);
                let mut agg = StepStats::default();
                for _ in 0..plan.accum_steps {
                    let tr = &mut self.trainers[ti];
                    let w = &mut tr.workers[wi];
                    w.sampler.next_batch(&self.corpus, &mut self.batch_buf);
                    let s = self.engine.grad_step(
                        &w.state.params,
                        &self.batch_buf,
                        &mut self.grad_scratch,
                    )?;
                    for (a, g) in self.accum_scratch.iter_mut().zip(&self.grad_scratch) {
                        *a += *g / plan.accum_steps as f32;
                    }
                    agg.loss += s.loss / plan.accum_steps as f64;
                    agg.grad_sq_norm += s.grad_sq_norm / plan.accum_steps as f64;
                    agg.sigma2 += s.sigma2 / plan.accum_steps as f64;
                    agg.ip_var += s.ip_var / plan.accum_steps as f64;
                }
                let tr = &mut self.trainers[ti];
                let w = &mut tr.workers[wi];
                self.engine.apply_update(&mut w.state, lr, &self.accum_scratch)?;
                agg
            } else {
                let tr = &mut self.trainers[ti];
                let w = &mut tr.workers[wi];
                w.sampler.next_batch(&self.corpus, &mut self.batch_buf);
                self.engine.train_step(&mut w.state, lr, &self.batch_buf)?
            };

            // virtual time: accum_steps micro-steps on this worker's node,
            // with optional dynamic-workload jitter (truncated at -3 sigma
            // so time never goes negative)
            let jitter = self.cfg.cluster.step_jitter;
            let tr = &mut self.trainers[ti];
            let w = &tr.workers[wi];
            let mut dt = self.nodes[w.node].step_time(plan.micro_batch, width - 1)
                * plan.accum_steps as f64;
            if jitter > 0.0 {
                let z = self.rng.normal().clamp(-3.0, 3.0);
                dt *= (1.0 + jitter * z).max(0.05);
            }
            self.clock.advance(w.clock_slot, dt);

            // adaptive-batching statistics (Algorithm 3 line 31)
            tr.controller.observe(&stats, plan.effective_batch());

            self.total_samples += plan.effective_batch() as u64;
            let global_step = tr.inner_steps_done + 1;
            self.recorder.steps.push(StepRecord {
                global_step,
                outer_step: outer_t,
                trainer: ti,
                worker: wi,
                batch: plan.micro_batch,
                requested_batch: tr.controller.requested(),
                accum_steps: plan.accum_steps,
                loss: stats.loss,
                grad_sq_norm: stats.grad_sq_norm,
                sigma2: stats.sigma2,
                virtual_time_s: self.clock.time(tr.workers[wi].clock_slot),
            });
        }
        self.trainers[ti].inner_steps_done += 1;
        Ok(())
    }

    /// MIT merge round (Algorithms 1-2).
    fn maybe_merge(&mut self, outer_t: u64) -> Result<()> {
        let requests: Vec<(usize, usize)> = self
            .trainers
            .iter()
            .filter(|t| t.alive)
            .map(|t| (t.id, t.requested_batch()))
            .collect();
        let policy = match self.cfg.algo.merge.policy {
            crate::config::MergeSelect::WorstByBatch => MergePolicy::WorstByBatch,
            crate::config::MergeSelect::Random => MergePolicy::Random,
        };
        let selected = check_merge_with_policy(
            &requests,
            self.cfg.algo.merge.w,
            self.cfg.algo.merge.min_trainers,
            policy,
            &mut self.rng,
        );
        if selected.len() < 2 {
            return Ok(());
        }

        // barrier every worker of the merging trainers + transfer time
        let param_bytes = (self.engine.param_count() * 4) as u64;
        let slots: Vec<usize> = selected
            .iter()
            .flat_map(|&id| self.trainers[id].workers.iter().map(|w| w.clock_slot))
            .collect();
        let bytes = (selected.len() as u64 - 1) * param_bytes;
        let t_after = self.clock.barrier(&slots, self.net.transfer_time(bytes));
        self.ledger.record(CommEvent {
            kind: CommKind::Merge,
            at_virtual_s: t_after,
            bytes,
            participants: selected.len(),
            at_inner_step: self.total_samples,
        });

        // weighted merge over the selected trainers' parameters
        let outcome = {
            // split borrows: collect (id, b_req) first, then build the
            // mutable member list in id order
            let reqs: Vec<(usize, usize)> = selected
                .iter()
                .map(|&id| (id, self.trainers[id].requested_batch()))
                .collect();
            let mut members: Vec<(usize, usize, &mut [f32])> = Vec::new();
            // safe split of multiple &mut trainers via split_at_mut walk
            let mut rest: &mut [Trainer] = &mut self.trainers;
            let mut base = 0usize;
            let mut sorted = selected.clone();
            sorted.sort_unstable();
            for id in sorted {
                let local = id - base;
                let tmp = rest;
                let (head, tail) = tmp.split_at_mut(local + 1);
                let tr = &mut head[local];
                let b = reqs.iter().find(|(i, _)| *i == id).unwrap().1;
                members.push((id, b, tr.params.as_mut_slice()));
                rest = tail;
                base = id + 1;
            }
            do_merge(&mut members)
        };

        // consume the non-representative trainers
        for &dead in &outcome.removed {
            self.trainers[dead].alive = false;
        }
        // the representative keeps the union of the merged shards and its
        // own optimizer trajectory (Algorithm 2 line 9); its outer
        // momentum is reset since the parameters jumped
        let shard_refs: Vec<&crate::data::Shard> = selected
            .iter()
            .map(|&id| &self.trainers[id].shard)
            .collect();
        let merged_shard = union_shards(&shard_refs);
        let rep = outcome.representative;
        {
            let m = self.trainers[rep].workers.len();
            let worker_shards = merged_shard.split(m);
            for (w, ws) in self.trainers[rep]
                .workers
                .iter_mut()
                .zip(worker_shards.into_iter())
            {
                w.sampler = crate::data::BatchSampler::new(ws, self.rng.fork(0xABCD + rep as u64));
            }
            self.trainers[rep].shard = merged_shard;
            self.trainers[rep].outer.reset();
        }

        crate::info!(
            "outer {outer_t}: merged {:?} -> representative {rep} ({} trainers left)",
            outcome.removed,
            self.live_trainers()
        );
        self.recorder.merges.push(MergeRecord {
            outer_step: outer_t,
            merged: outcome.removed.clone(),
            representative: rep,
            trainers_left: self.live_trainers(),
            virtual_time_s: t_after,
        });
        Ok(())
    }

    /// Evaluate worker-0 parameters of trainer `ti` (mid-outer-step eval,
    /// the paper's every-10-steps cadence). Returns true if target reached.
    fn evaluate(&mut self, ti: usize, outer_t: u64) -> Result<bool> {
        let params_ptr: Vec<f32> = self.trainers[ti].workers[0].state.params.clone();
        self.eval_params(&params_ptr, ti, outer_t)
    }

    /// Evaluate the trainer's outer parameters (post-sync).
    fn evaluate_trainer_params(&mut self, ti: usize, outer_t: u64) -> Result<bool> {
        let params: Vec<f32> = self.trainers[ti].params.clone();
        self.eval_params(&params, ti, outer_t)
    }

    fn eval_params(&mut self, params: &[f32], ti: usize, outer_t: u64) -> Result<bool> {
        let eb = self.engine.eval_batch();
        let width = self.val_corpus.width();
        let mut eval_rng = Rng::new(self.cfg.seed ^ 0xE7A1 ^ outer_t);
        let mut loss_acc = 0.0;
        let n = self.cfg.run.eval_batches.max(1);
        let mut buf = TokenBatch::new(eb, width);
        for _ in 0..n {
            for row in 0..eb {
                let ix = eval_rng.below(self.val_corpus.len() as u64) as usize;
                buf.row_mut(row).copy_from_slice(self.val_corpus.sequence(ix));
            }
            loss_acc += self.engine.eval_loss(params, &buf)?;
        }
        let loss = loss_acc / n as f64;
        let ppl = perplexity(loss);
        let tr = &self.trainers[ti];
        let vt = tr
            .workers
            .iter()
            .map(|w| self.clock.time(w.clock_slot))
            .fold(0.0f64, f64::max);
        self.recorder.evals.push(EvalRecord {
            global_step: tr.inner_steps_done,
            outer_step: outer_t,
            trainer: ti,
            loss,
            perplexity: ppl,
            virtual_time_s: vt,
            comm_count: self.ledger.count(),
            comm_bytes: self.ledger.total_bytes(),
        });
        Ok(self.cfg.run.target_ppl > 0.0 && ppl <= self.cfg.run.target_ppl)
    }

    /// Final summary.
    pub fn result(&self) -> RunResult {
        RunResult {
            name: self.cfg.name.clone(),
            method: self.cfg.algo.method,
            best_ppl: self.recorder.best_perplexity().unwrap_or(f64::INFINITY),
            final_ppl: self.recorder.final_perplexity().unwrap_or(f64::INFINITY),
            total_inner_steps: self
                .trainers
                .iter()
                .map(|t| t.inner_steps_done)
                .max()
                .unwrap_or(0),
            total_samples: self.total_samples,
            comm_count: self.ledger.count(),
            comm_bytes: self.ledger.total_bytes(),
            virtual_time_s: self.clock.max_time(),
            trainers_left: self.live_trainers(),
            time_to_target: if self.cfg.run.target_ppl > 0.0 {
                self.recorder.time_to_target(self.cfg.run.target_ppl)
            } else {
                None
            },
        }
    }
}

/// Convenience: build engine + coordinator from a config and run it.
pub fn run_experiment(cfg: Config) -> Result<RunResult> {
    let engine = crate::engine::build_engine(&cfg)?;
    let mut coord = Coordinator::new(cfg, engine)?;
    let result = coord.run()?;
    if let Some(dir) = coord.cfg.out_dir.clone() {
        let base = format!("{dir}/{}", coord.cfg.name);
        coord.recorder.write_jsonl(&format!("{base}.jsonl"))?;
        coord.recorder.write_eval_csv(&format!("{base}.csv"))?;
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn mock_cfg() -> Config {
        let mut cfg = presets::mock_default();
        cfg.algo.outer_steps = 8;
        cfg.algo.inner_steps = 15;
        cfg.algo.lr_inner = 0.15; // converge fast enough that the norm
                                  // test's request visibly grows in-test
        cfg.algo.num_trainers = 4;
        cfg.algo.workers_per_trainer = 2;
        cfg.algo.merge.frequency = 2;
        cfg.run.eval_every = 5;
        cfg
    }

    fn run_with(cfg: Config) -> (RunResult, Recorder, usize) {
        let engine = crate::engine::build_engine(&cfg).unwrap();
        let mut c = Coordinator::new(cfg, engine).unwrap();
        let r = c.run().unwrap();
        let rec = c.recorder.clone();
        (r, rec, c.live_trainers())
    }

    #[test]
    fn adloco_run_descends_and_merges() {
        let (r, rec, live) = run_with(mock_cfg());
        assert!(r.best_ppl < rec.evals.first().unwrap().perplexity);
        assert!(live < 4, "merging should consolidate trainers");
        assert!(!rec.merges.is_empty());
        assert!(r.comm_count > 0);
        assert!(r.virtual_time_s > 0.0);
    }

    #[test]
    fn adaptive_batch_grows() {
        let (_, rec, _) = run_with(mock_cfg());
        let first_req = rec.steps.first().unwrap().requested_batch;
        let last_req = rec.steps.last().unwrap().requested_batch;
        assert!(
            last_req > first_req,
            "requested batch should grow: {first_req} -> {last_req}"
        );
    }

    #[test]
    fn diloco_policy_disables_features() {
        let mut cfg = mock_cfg();
        cfg.algo.method = Method::DiLoCo;
        let resolved = resolve_policy(&cfg);
        assert!(!resolved.algo.batching.adaptive);
        assert!(!resolved.algo.merge.enabled);
        assert!(!resolved.algo.switch.enabled);

        let (r, rec, live) = run_with(cfg);
        assert_eq!(live, 4, "DiLoCo must not merge");
        assert!(rec.merges.is_empty());
        // fixed batch: every step at algo.fixed_batch
        let fixed = resolved.algo.fixed_batch;
        assert!(rec.steps.iter().all(|s| s.batch == fixed.min(16)));
        assert!(r.best_ppl.is_finite());
    }

    #[test]
    fn localsgd_uses_average_outer() {
        let mut cfg = mock_cfg();
        cfg.algo.method = Method::LocalSgd;
        let resolved = resolve_policy(&cfg);
        assert_eq!(resolved.algo.outer_opt, crate::config::OuterOptKind::Average);
        let (r, _, _) = run_with(cfg);
        assert!(r.best_ppl.is_finite());
    }

    #[test]
    fn switch_mode_engages_at_large_requests() {
        let mut cfg = mock_cfg();
        // tiny node budget + warm-started request past 2*max_batch forces
        // SwitchMode from the first plan
        for n in &mut cfg.cluster.nodes {
            n.max_batch = 2;
        }
        cfg.algo.batching.initial_batch = 10;
        cfg.algo.batching.max_request = 16; // bound accumulation depth
        cfg.algo.outer_steps = 8;
        let (_, rec, _) = run_with(cfg);
        assert!(
            rec.steps.iter().any(|s| s.accum_steps > 1),
            "switch mode never engaged"
        );
        // micro batch never exceeds the node budget
        assert!(rec.steps.iter().all(|s| s.batch <= 2));
    }

    #[test]
    fn switch_disabled_never_accumulates() {
        let mut cfg = mock_cfg();
        for n in &mut cfg.cluster.nodes {
            n.max_batch = 2;
        }
        cfg.algo.batching.max_request = 16;
        cfg.algo.switch.enabled = false;
        let (_, rec, _) = run_with(cfg);
        assert!(rec.steps.iter().all(|s| s.accum_steps == 1));
    }

    #[test]
    fn merge_preserves_param_dimension_and_counts() {
        let cfg = mock_cfg();
        let engine = crate::engine::build_engine(&cfg).unwrap();
        let mut c = Coordinator::new(cfg, engine).unwrap();
        let p = c.engine.param_count();
        for t in 1..=6u64 {
            c.step_outer(t).unwrap();
        }
        for tr in c.trainers.iter().filter(|t| t.alive) {
            assert_eq!(tr.params.len(), p);
        }
        // every merge recorded the surviving count correctly
        for m in &c.recorder.merges {
            assert!(m.trainers_left >= c.cfg.algo.merge.min_trainers);
        }
    }

    #[test]
    fn min_trainers_floor_respected() {
        let mut cfg = mock_cfg();
        cfg.algo.merge.min_trainers = 3;
        cfg.algo.merge.w = 4;
        cfg.algo.outer_steps = 10;
        let (_, _, live) = run_with(cfg);
        assert!(live >= 3, "live {live} below min_trainers floor");
    }

    #[test]
    fn comm_ledger_has_outer_syncs() {
        let cfg = mock_cfg(); // workers_per_trainer = 2 -> real syncs
        let engine = crate::engine::build_engine(&cfg).unwrap();
        let mut c = Coordinator::new(cfg, engine).unwrap();
        c.run().unwrap();
        assert!(c.ledger().count_kind(CommKind::OuterSync) > 0);
    }

    #[test]
    fn deterministic_runs() {
        let (r1, rec1, _) = run_with(mock_cfg());
        let (r2, rec2, _) = run_with(mock_cfg());
        assert_eq!(r1.comm_count, r2.comm_count);
        assert_eq!(r1.total_samples, r2.total_samples);
        assert_eq!(rec1.evals.len(), rec2.evals.len());
        for (a, b) in rec1.evals.iter().zip(rec2.evals.iter()) {
            assert!((a.perplexity - b.perplexity).abs() < 1e-9);
        }
    }

    #[test]
    fn random_merge_policy_runs_and_merges() {
        let mut cfg = mock_cfg();
        cfg.algo.merge.policy = crate::config::MergeSelect::Random;
        let (r, rec, live) = run_with(cfg);
        assert!(r.best_ppl.is_finite());
        assert!(live < 4, "random policy must still merge");
        assert!(!rec.merges.is_empty());
    }

    #[test]
    fn target_ppl_stops_early() {
        let mut cfg = mock_cfg();
        cfg.run.target_ppl = 1e14; // above the e^30 perplexity clamp => trivially reached
        let (r, _, _) = run_with(cfg);
        assert!(r.time_to_target.is_some());
        assert!(r.total_inner_steps <= 15, "should stop within first outer step");
    }

    #[test]
    fn virtual_time_monotone_in_steps() {
        let (_, rec, _) = run_with(mock_cfg());
        // per (trainer, worker) stream, virtual time must be nondecreasing
        use std::collections::HashMap;
        let mut last: HashMap<(usize, usize), f64> = HashMap::new();
        for s in &rec.steps {
            let key = (s.trainer, s.worker);
            if let Some(prev) = last.get(&key) {
                assert!(s.virtual_time_s >= *prev);
            }
            last.insert(key, s.virtual_time_s);
        }
    }

}
