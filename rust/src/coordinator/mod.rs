//! The AdLoCo coordinator (paper Algorithm 3): the run loop that composes
//! adaptive batching, SwitchMode accumulation, multi-instance merging and
//! DiLoCo-style outer optimization over a simulated cluster.
//!
//! The same loop realizes every method and ablation arm in the paper via
//! the config knobs (see [`resolve_policy`]):
//!
//! | run                    | adaptive | merge | switch | outer opt |
//! |------------------------|----------|-------|--------|-----------|
//! | AdLoCo (full)          | on       | on    | on     | Nesterov  |
//! | DiLoCo baseline        | off      | off   | off    | Nesterov  |
//! | LocalSGD baseline      | off      | off   | off    | Average   |
//! | Fig. 2 −adaptive       | off      | on    | on     | Nesterov  |
//! | Fig. 2 −merge          | on       | off   | on     | Nesterov  |
//! | Fig. 2 −switch         | on       | on    | off    | Nesterov  |
//!
//! Timekeeping is virtual (DESIGN.md §3): compute advances each worker's
//! clock through the node's step-time model; outer syncs and merges are
//! barriers plus modeled all-reduce/transfer time; the ledger records
//! every communication for the C(N) analyses (Theorem 2).
//!
//! Two run loops drive the same numerics (DESIGN.md §3.1–§3.2):
//!
//! * **lockstep** — the reference walk: trainers and their workers are
//!   iterated in fixed program order. Retained as the bit-exact
//!   regression anchor.
//! * **event** — a discrete-event scheduler: workers post `StepDone`
//!   events into a priority queue and the coordinator consumes them in
//!   virtual-time order, with `SyncArrive`/`MergeArrive` rendezvous at
//!   the outer boundaries. On a static cluster it reproduces the
//!   lockstep run bit-for-bit (per-worker RNG streams make the numerics
//!   scheduling-order independent — DESIGN.md §3.4); with a
//!   `cluster.scenario` it models stragglers, node churn and
//!   time-varying links, and accounts per-worker busy/wait/preempted
//!   time for the utilization report.
//!
//! The event path additionally hosts the **parallel execution runtime**
//! (DESIGN.md §6): with `run.threads > 1`, each active worker's
//! inner-step chain for the outer round runs on a thread pool — workers
//! are independent between sync/merge rendezvous, own their RNG streams
//! and model state, and all records flush in canonical order, so a
//! parallel run is bit-identical to the serial one
//! (`tests/determinism_parallel.rs`). Threads buy wall-clock only; they
//! never change a result.

use crate::batching::{plan_step, StepPlan};
use crate::config::{Config, Method, SchedulerKind};
use crate::data::{make_shards, shard::union_shards, Corpus, CorpusSpec, TokenBatch};
use crate::engine::{StepStats, TrainEngine};
use crate::merge::{check_merge_with_policy, do_merge, MergePolicy};
use crate::metrics::{perplexity, EvalRecord, MergeRecord, Recorder, StepRecord, UtilRecord};
use crate::simulator::{
    assign_workers, node_models, CommEvent, CommKind, CommLedger, EventQueue, NetworkModel,
    NodeModel, Scenario, SimEvent, VirtualClock,
};
use crate::trainer::{Trainer, Worker};
use crate::util::Rng;
use anyhow::Result;
use std::collections::BTreeMap;

/// Outcome summary of a run (full series live in the recorder).
///
/// Every field except `wall_clock_s` and `threads` is covered by the
/// determinism contract (DESIGN.md §6): it is a pure function of the
/// config and must be bit-identical across schedulers and thread counts.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Config name the run was launched under.
    pub name: String,
    /// Coordination method (AdLoCo / DiLoCo / LocalSGD).
    pub method: Method,
    /// Best validation perplexity seen by any live trainer.
    pub best_ppl: f64,
    /// Perplexity of the last evaluation of the run.
    pub final_ppl: f64,
    /// Max per-trainer inner-step count reached.
    pub total_inner_steps: u64,
    /// Samples consumed across the run (the N axis of Theorem 2).
    pub total_samples: u64,
    /// Communication events recorded in the ledger.
    pub comm_count: usize,
    /// Total bytes moved across all recorded communications.
    pub comm_bytes: u64,
    /// Simulated wall-clock (max over worker virtual clocks).
    pub virtual_time_s: f64,
    /// Live trainers at the end (merging consolidates them).
    pub trainers_left: usize,
    /// Sum of barrier-wait + churn-preemption seconds across all workers
    /// (the cluster-efficiency axis of the dynamic-workload scenarios).
    pub total_idle_s: f64,
    /// Mean per-worker busy fraction.
    pub mean_utilization: f64,
    /// (step, time, comms) at which target_ppl was first reached, if ever.
    pub time_to_target: Option<(u64, f64, usize)>,
    /// Host wall-clock seconds spent inside `Coordinator::run` — NOT part
    /// of the determinism contract (it varies run to run); the observable
    /// behind the §Perf speedup table.
    pub wall_clock_s: f64,
    /// Resolved thread count the run executed with (`run.threads`, with
    /// 0 resolved via `RUN_THREADS`). Not part of the determinism
    /// contract's compared payload, but parallel runs must reproduce the
    /// serial payload bit-for-bit.
    pub threads: usize,
}

/// Apply the method's policy constraints to a copy of the config
/// (DiLoCo = AdLoCo minus adaptivity/merging/switching; LocalSGD further
/// degrades the outer optimizer to plain averaging — §3.1, §3.2).
pub fn resolve_policy(cfg: &Config) -> Config {
    let mut out = cfg.clone();
    match cfg.algo.method {
        Method::AdLoCo => {}
        Method::DiLoCo => {
            out.algo.batching.adaptive = false;
            out.algo.merge.enabled = false;
            out.algo.switch.enabled = false;
        }
        Method::LocalSgd => {
            out.algo.batching.adaptive = false;
            out.algo.merge.enabled = false;
            out.algo.switch.enabled = false;
            out.algo.outer_opt = crate::config::OuterOptKind::Average;
        }
    }
    out
}

/// Per-trainer bookkeeping of one event-driven outer step.
struct TrainerRun {
    plan: StepPlan,
    /// Inner steps this trainer executes this outer step.
    target: u64,
    /// `inner_steps_done` at the start of the outer step.
    start_done: u64,
    /// Worker whose parameters mid-loop evals read (first active; worker
    /// 0 on a static cluster, matching the lockstep path).
    eval_worker: usize,
    n_active: usize,
    /// Completed steps: (step, worker, stats, completion time). Folded
    /// into the controller in canonical (step, worker) order at the
    /// outer boundary — the exact order the lockstep walk produces.
    stats: Vec<(u64, usize, StepStats, f64)>,
    /// Mid-loop evals buffered until the canonical flush, keyed by step.
    evals: Vec<(u64, EvalRecord)>,
    /// Pending mid-loop evals: step -> arrival times + params snapshot.
    pending: BTreeMap<u64, PendingEval>,
}

struct PendingEval {
    times: Vec<f64>,
    remaining: usize,
    params: Vec<f32>,
}

/// Shared read-only state a worker chain borrows from the coordinator
/// while it runs on a pool thread (DESIGN.md §6). `Copy` so each thread
/// captures its own handle.
#[derive(Clone, Copy)]
struct ChainCtx<'a> {
    engine: &'a dyn TrainEngine,
    corpus: &'a Corpus,
    nodes: &'a [NodeModel],
    scenario: &'a Scenario,
    lr_schedule: &'a crate::schedule::Schedule,
    lr_inner: f64,
    step_jitter: f64,
    eval_every: u64,
    cap: u64,
    width: usize,
}

/// Per-chain launch parameters, copied out of the coordinator before the
/// borrow split (everything here is plain data; the worker itself is the
/// one `&mut` the chain owns).
#[derive(Clone, Copy)]
struct ChainTask {
    ti: usize,
    wi: usize,
    slot: usize,
    node: usize,
    /// Worker virtual clock at the start of the outer step.
    start_time: f64,
    /// Carried-in busy/preempted accumulators: the chain continues the
    /// exact f64 addition sequence the serial loop would perform, so the
    /// utilization accounting stays bit-identical (DESIGN.md §6).
    busy_start: f64,
    preempted_start: f64,
    plan: StepPlan,
    target: u64,
    start_done: u64,
    /// True for the trainer's designated eval worker: snapshot parameters
    /// at each mid-loop evaluation step.
    snapshot_params: bool,
}

/// What one worker chain hands back to the coordinator at the join.
struct ChainOutput {
    ti: usize,
    wi: usize,
    slot: usize,
    /// (step, stats, completion time) for each executed inner step.
    stats: Vec<(u64, StepStats, f64)>,
    /// Parameter snapshots at mid-loop eval steps (eval worker only).
    snaps: Vec<(u64, Vec<f32>)>,
    end_time: f64,
    busy_end: f64,
    preempted_end: f64,
}

/// Per-step scratch the engine work writes through (`grad`/`accum` may
/// be empty when the plan never accumulates).
struct StepScratch<'a> {
    buf: &'a mut TokenBatch,
    grad: &'a mut [f32],
    accum: &'a mut [f32],
}

/// The engine work of one inner step of worker `w`: sample a batch (or
/// `accum_steps` of them under SwitchMode), run the gradient
/// computation, apply the update. THE single implementation — the
/// lockstep walk, the serial event loop and the parallel chains all
/// call this, so their numerics cannot drift apart (DESIGN.md §6).
/// Engine noise comes from the worker's private stream.
fn exec_step(
    engine: &dyn TrainEngine,
    corpus: &Corpus,
    w: &mut Worker,
    plan: &StepPlan,
    lr: f64,
    scratch: StepScratch<'_>,
) -> Result<StepStats> {
    if plan.accum_steps > 1 {
        // SwitchMode: accumulate accum_steps gradients at the micro
        // batch, then one optimizer commit (§4.2).
        scratch.accum.iter_mut().for_each(|x| *x = 0.0);
        let mut agg = StepStats::default();
        for _ in 0..plan.accum_steps {
            w.sampler.next_batch(corpus, scratch.buf);
            let s = engine.grad_step(
                &w.state.params,
                scratch.buf,
                scratch.grad,
                &mut w.noise_rng,
            )?;
            for (a, g) in scratch.accum.iter_mut().zip(scratch.grad.iter()) {
                *a += *g / plan.accum_steps as f32;
            }
            agg.loss += s.loss / plan.accum_steps as f64;
            agg.grad_sq_norm += s.grad_sq_norm / plan.accum_steps as f64;
            agg.sigma2 += s.sigma2 / plan.accum_steps as f64;
            agg.ip_var += s.ip_var / plan.accum_steps as f64;
        }
        engine.apply_update(&mut w.state, lr, scratch.accum)?;
        Ok(agg)
    } else {
        w.sampler.next_batch(corpus, scratch.buf);
        engine.train_step(&mut w.state, lr, scratch.buf, &mut w.noise_rng)
    }
}

/// Compute-time of one inner step (node model × accumulation depth ×
/// optional jitter from the worker's private time stream) — the single
/// implementation behind both schedulers and the parallel chains.
fn step_compute_time(
    node: &NodeModel,
    plan: &StepPlan,
    width: usize,
    jitter: f64,
    time_rng: &mut Rng,
) -> f64 {
    let mut dt = node.step_time(plan.micro_batch, width - 1) * plan.accum_steps as f64;
    if jitter > 0.0 {
        // truncated at -3 sigma so time never goes negative
        let z = time_rng.normal().clamp(-3.0, 3.0);
        dt *= (1.0 + jitter * z).max(0.05);
    }
    dt
}

/// One worker's full inner-step chain for an outer round — the unit of
/// parallelism (DESIGN.md §6). Performs, draw for draw and flop for
/// flop, what the serial event loop executes for this worker, by
/// calling the same [`exec_step`] / [`step_compute_time`] /
/// `Scenario` primitives in the same per-stream order (time_rng:
/// jitter then straggler per step; noise_rng: engine draws per step;
/// virtual-time recurrence via `compute_span` from the previous step's
/// end). Scratch buffers are chain-local, so chains share nothing
/// mutable.
fn run_worker_chain(ctx: ChainCtx<'_>, task: ChainTask, w: &mut Worker) -> Result<ChainOutput> {
    crate::util::logger::set_thread_context(format!("t{}.w{}", task.ti, task.wi));
    let plan = task.plan;
    // chain-local scratch; the gradient buffers are only needed on the
    // SwitchMode (accumulating) path
    let (mut grad, mut accum) = if plan.accum_steps > 1 {
        let p = ctx.engine.param_count();
        (vec![0.0f32; p], vec![0.0f32; p])
    } else {
        (Vec::new(), Vec::new())
    };
    let mut buf = TokenBatch::new(plan.micro_batch, ctx.width);
    let mut stats_out: Vec<(u64, StepStats, f64)> = Vec::with_capacity(task.target as usize);
    let mut snaps: Vec<(u64, Vec<f32>)> = Vec::new();
    let mut now = task.start_time;
    let mut busy = task.busy_start;
    let mut preempted = task.preempted_start;
    let node_model = &ctx.nodes[task.node];

    for step in 1..=task.target {
        // ---- timing (serial: step_duration + schedule_step_end) --------
        let mut dt =
            step_compute_time(node_model, &plan, ctx.width, ctx.step_jitter, &mut w.time_rng);
        dt *= ctx.scenario.straggler_factor(&mut w.time_rng);
        let (end, stall) = ctx.scenario.compute_span(task.node, now, dt);
        busy += dt;
        preempted += stall;
        now = end;

        // ---- compute (the shared exec_step, like the serial paths) -----
        let lr = ctx.lr_schedule.lr(ctx.lr_inner, task.start_done + step);
        let stats = exec_step(
            ctx.engine,
            ctx.corpus,
            w,
            &plan,
            lr,
            StepScratch { buf: &mut buf, grad: &mut grad, accum: &mut accum },
        )?;
        stats_out.push((step, stats, now));

        // ---- mid-loop eval snapshot (same gating as the serial loop) ---
        if task.snapshot_params
            && ctx.eval_every > 0
            && step % ctx.eval_every == 0
            && !(ctx.cap > 0 && task.start_done + step >= ctx.cap)
        {
            snaps.push((step, w.state.params.clone()));
        }
    }
    crate::util::logger::clear_thread_context();
    Ok(ChainOutput {
        ti: task.ti,
        wi: task.wi,
        slot: task.slot,
        stats: stats_out,
        snaps,
        end_time: now,
        busy_end: busy,
        preempted_end: preempted,
    })
}

/// The AdLoCo run loop over the simulated cluster: owns the trainer pool,
/// the engine, the virtual clocks, the data pipeline and the recorders.
pub struct Coordinator {
    cfg: Config,
    engine: Box<dyn TrainEngine>,
    corpus: Corpus,
    val_corpus: Corpus,
    trainers: Vec<Trainer>,
    clock: VirtualClock,
    nodes: Vec<NodeModel>,
    net: NetworkModel,
    scenario: Scenario,
    ledger: CommLedger,
    /// Every record stream the run produces (steps, evals, merges,
    /// utilization, notes, wall-clock).
    pub recorder: Recorder,
    rng: Rng,
    /// Reusable buffers (hot path: no allocation per step).
    delta_scratch: Vec<f32>,
    grad_scratch: Vec<f32>,
    accum_scratch: Vec<f32>,
    /// One reusable token buffer per (batch, width) seen — bounded by the
    /// engine ladder, so interleaved trainers with different plans (the
    /// event scheduler) don't reallocate per step.
    batch_bufs: Vec<TokenBatch>,
    /// Samples consumed across the run (the N axis of Theorem 2).
    total_samples: u64,
    /// Inner-lr schedule (evaluated on each trainer's inner-step count).
    lr_schedule: crate::schedule::Schedule,
    /// Per-clock-slot time accounting (virtual seconds).
    busy_s: Vec<f64>,
    wait_s: Vec<f64>,
    comm_s: Vec<f64>,
    preempted_s: Vec<f64>,
    /// Resolved thread count for the parallel runtime (>= 1).
    threads: usize,
    /// Host wall-clock of the last `run()` call (perf reporting only).
    run_wall_s: f64,
}

impl Coordinator {
    /// Build a coordinator (generates data, shards it, places workers).
    pub fn new(cfg: Config, engine: Box<dyn TrainEngine>) -> Result<Coordinator> {
        let cfg = resolve_policy(&cfg);
        cfg.validate()?;
        let a = &cfg.algo;

        let seq_width_minus1 = cfg.data.seq_len;
        let corpus = Corpus::generate(CorpusSpec::new(
            cfg.data.corpus_sequences,
            seq_width_minus1,
            cfg.data.vocab,
            cfg.data.zipf_s,
            cfg.data.seed,
        ));
        let val_corpus = Corpus::generate(CorpusSpec::new(
            cfg.data.val_sequences.max(engine.eval_batch()),
            seq_width_minus1,
            cfg.data.vocab,
            cfg.data.zipf_s,
            cfg.data.seed ^ 0xFACE,
        ));

        let mut rng = Rng::new(cfg.seed);
        let k = a.num_trainers;
        let m = a.workers_per_trainer;
        let shards = make_shards(corpus.len(), k, cfg.data.shard_fraction, &mut rng);
        let placement = assign_workers(k * m, cfg.cluster.nodes.len());

        let mut trainers = Vec::with_capacity(k);
        for (i, shard) in shards.into_iter().enumerate() {
            let nodes_of_workers: Vec<usize> =
                (0..m).map(|j| placement[i * m + j]).collect();
            trainers.push(Trainer::new(
                i,
                engine.as_ref(),
                a,
                shard,
                &nodes_of_workers,
                i * m,
                // trainer 0 uses the canonical init; others are
                // independent initializations (MIT §4.1)
                i as u64,
                &mut rng,
            ));
        }

        let p = engine.param_count();
        let threads = cfg.run.effective_threads();
        let mut recorder = Recorder::new();
        recorder.note("engine", engine.name());
        recorder.note("method", a.method.as_str());
        recorder.note("config", cfg.name.clone());
        recorder.note("scheduler", cfg.run.scheduler.as_str());
        recorder.note("threads", threads.to_string());

        Ok(Coordinator {
            clock: VirtualClock::new(k * m),
            nodes: node_models(&cfg.cluster),
            net: NetworkModel {
                latency_s: cfg.cluster.net_latency_s,
                bandwidth_bps: cfg.cluster.net_bandwidth_bps,
            },
            scenario: Scenario::compile(&cfg.cluster.scenario, cfg.cluster.nodes.len()),
            ledger: CommLedger::default(),
            recorder,
            rng,
            delta_scratch: vec![0.0; p],
            grad_scratch: vec![0.0; p],
            accum_scratch: vec![0.0; p],
            batch_bufs: Vec::new(),
            total_samples: 0,
            lr_schedule: crate::schedule::Schedule::from_config(
                &cfg.algo.lr_schedule,
                (cfg.algo.outer_steps * cfg.algo.inner_steps) as u64,
            ),
            busy_s: vec![0.0; k * m],
            wait_s: vec![0.0; k * m],
            comm_s: vec![0.0; k * m],
            preempted_s: vec![0.0; k * m],
            threads,
            run_wall_s: 0.0,
            cfg,
            engine,
            corpus,
            val_corpus,
            trainers,
        })
    }

    /// The (policy-resolved) config this coordinator runs.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// The communication ledger accumulated so far.
    pub fn ledger(&self) -> &CommLedger {
        &self.ledger
    }

    /// Resolved thread count of the parallel runtime (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Trainers still alive (not consumed by a merge).
    pub fn live_trainers(&self) -> usize {
        self.trainers.iter().filter(|t| t.alive).count()
    }

    /// The effective hardware max_batch for a trainer: the smallest node
    /// budget among its workers, capped by the engine's compiled ladder.
    fn max_batch_for(&self, t: &Trainer) -> usize {
        let node_min = t
            .workers
            .iter()
            .map(|w| self.nodes[w.node].max_batch)
            .min()
            .unwrap_or(1);
        node_min.min(self.engine.max_batch()).max(1)
    }

    /// Barrier with utilization accounting: members wait for the slowest
    /// (wait time) then pay the transfer (comm time). Numerically exactly
    /// `VirtualClock::barrier`.
    fn barrier_tracked(&mut self, members: &[usize], extra: f64) -> f64 {
        let t_start = members
            .iter()
            .map(|&w| self.clock.time(w))
            .fold(0.0_f64, f64::max);
        for &w in members {
            self.wait_s[w] += t_start - self.clock.time(w);
            self.comm_s[w] += extra;
        }
        self.clock.barrier(members, extra)
    }

    /// Run the full schedule (T outer steps of H inner steps), honouring
    /// the checkpoint/resume settings in `run` config.
    ///
    /// Scheduler/thread dispatch: serial lockstep keeps the reference
    /// walk; everything else goes through the event-equivalent path,
    /// which fans worker chains out across `run.threads` OS threads when
    /// threads > 1. A parallel lockstep run is legal because lockstep
    /// configs are static by validation and the event path is bit-equal
    /// to lockstep on static clusters (DESIGN.md §3.2, §6).
    pub fn run(&mut self) -> Result<RunResult> {
        let wall0 = std::time::Instant::now();
        let mut start = 1u64;
        if let Some(path) = self.cfg.run.resume_from.clone() {
            let cp = crate::checkpoint::Checkpoint::load(&path)?;
            start = cp.outer_step + 1;
            self.restore(&cp)?;
            crate::info!("resumed from {path} at outer step {}", cp.outer_step);
        }
        let outer_steps = self.cfg.algo.outer_steps as u64;
        let every = self.cfg.run.checkpoint_every as u64;
        for t in start..=outer_steps {
            let hit = match self.cfg.run.scheduler {
                SchedulerKind::Lockstep if self.threads <= 1 => self.step_outer(t)?,
                _ => self.step_outer_event(t)?,
            };
            if let Some(path) = self.cfg.run.checkpoint_path.clone() {
                if (every > 0 && t % every == 0) || t == outer_steps || hit {
                    self.snapshot(t).save(&path)?;
                    crate::debug!("checkpoint written to {path} at outer {t}");
                }
            }
            if hit {
                crate::info!("target perplexity reached at outer step {t}; stopping");
                break;
            }
        }
        self.record_utilization();
        self.run_wall_s = wall0.elapsed().as_secs_f64();
        self.recorder.wall_clock_s = self.run_wall_s;
        Ok(self.result())
    }

    /// Capture the trainer pool for checkpointing.
    pub fn snapshot(&self, outer_step: u64) -> crate::checkpoint::Checkpoint {
        use crate::checkpoint::{Checkpoint, TrainerSnapshot, WorkerSnapshot};
        Checkpoint {
            config_name: self.cfg.name.clone(),
            outer_step,
            total_samples: self.total_samples,
            comm_count: self.ledger.count() as u64,
            comm_bytes: self.ledger.total_bytes(),
            clock_times: (0..self.clock.len()).map(|w| self.clock.time(w)).collect(),
            trainers: self
                .trainers
                .iter()
                .filter(|t| t.alive)
                .map(|t| TrainerSnapshot {
                    id: t.id,
                    params: t.params.clone(),
                    outer_velocity: t.outer.velocity().to_vec(),
                    requested_batch: t.controller.requested(),
                    inner_steps_done: t.inner_steps_done,
                    workers: t
                        .workers
                        .iter()
                        .map(|w| WorkerSnapshot {
                            params: w.state.params.clone(),
                            m: w.state.m.clone(),
                            v: w.state.v.clone(),
                            step: w.state.step,
                        })
                        .collect(),
                })
                .collect(),
        }
    }

    /// Restore trainer state from a checkpoint. Trainers present in the
    /// coordinator but absent from the checkpoint were merged away before
    /// the snapshot and are marked dead. Data-pipeline position restarts
    /// from the config seed (see checkpoint module docs).
    pub fn restore(&mut self, cp: &crate::checkpoint::Checkpoint) -> Result<()> {
        use anyhow::ensure;
        let p = self.engine.param_count();
        for t in &mut self.trainers {
            t.alive = false;
        }
        for snap in &cp.trainers {
            ensure!(
                snap.id < self.trainers.len(),
                "checkpoint trainer id {} out of range (config has {})",
                snap.id,
                self.trainers.len()
            );
            ensure!(
                snap.params.len() == p,
                "checkpoint param count {} != engine {}",
                snap.params.len(),
                p
            );
            let t = &mut self.trainers[snap.id];
            ensure!(
                snap.workers.len() == t.workers.len(),
                "checkpoint worker count mismatch for trainer {}",
                snap.id
            );
            t.alive = true;
            t.params.copy_from_slice(&snap.params);
            t.outer.set_velocity(&snap.outer_velocity);
            t.controller.set_requested(snap.requested_batch);
            t.inner_steps_done = snap.inner_steps_done;
            for (w, ws) in t.workers.iter_mut().zip(snap.workers.iter()) {
                w.state.params.copy_from_slice(&ws.params);
                w.state.m.copy_from_slice(&ws.m);
                w.state.v.copy_from_slice(&ws.v);
                w.state.step = ws.step;
            }
        }
        for (w, &t) in cp.clock_times.iter().enumerate().map(|(i, t)| (i, t)) {
            if w < self.clock.len() {
                let cur = self.clock.time(w);
                if t > cur {
                    self.clock.advance(w, t - cur);
                }
            }
        }
        self.total_samples = cp.total_samples;
        Ok(())
    }

    // ------------------------------------------------------------------
    // shared building blocks (both schedulers)
    // ------------------------------------------------------------------

    /// The step plan this trainer uses for the whole outer step
    /// (Algorithm 3 lines 17-27 — b_req was stored at the previous one).
    fn plan_for(&self, ti: usize) -> StepPlan {
        let tr = &self.trainers[ti];
        let a = &self.cfg.algo;
        let b_req = if a.batching.adaptive { tr.requested_batch() } else { a.fixed_batch };
        let max_batch = self.max_batch_for(tr);
        plan_step(
            b_req,
            max_batch,
            a.switch.multiplier,
            a.switch.enabled,
            self.engine.supported_batches(),
        )
    }

    /// The engine work of one inner step of worker `wi` of trainer `ti`
    /// over the coordinator's shared scratch buffers — a thin borrow
    /// adapter around the shared [`exec_step`] (which the parallel
    /// chains call with chain-local scratch).
    fn exec_worker_step(
        &mut self,
        ti: usize,
        wi: usize,
        plan: &StepPlan,
        lr: f64,
    ) -> Result<StepStats> {
        let width = self.corpus.width();
        let bi = self.batch_buf_for(plan.micro_batch, width);
        exec_step(
            self.engine.as_ref(),
            &self.corpus,
            &mut self.trainers[ti].workers[wi],
            plan,
            lr,
            StepScratch {
                buf: &mut self.batch_bufs[bi],
                grad: &mut self.grad_scratch,
                accum: &mut self.accum_scratch,
            },
        )
    }

    /// Index of the reusable token buffer for this (batch, width),
    /// creating it on first use. The set of sizes is bounded by the
    /// engine's batch ladder, so the cache stays tiny.
    fn batch_buf_for(&mut self, batch: usize, width: usize) -> usize {
        match self
            .batch_bufs
            .iter()
            .position(|b| b.batch == batch && b.width == width)
        {
            Some(i) => i,
            None => {
                self.batch_bufs.push(TokenBatch::new(batch, width));
                self.batch_bufs.len() - 1
            }
        }
    }

    /// Compute-time of one inner step of worker `wi` — a borrow adapter
    /// around the shared [`step_compute_time`] (used by both schedulers;
    /// the parallel chains call it directly).
    fn step_duration(&mut self, ti: usize, wi: usize, plan: &StepPlan) -> f64 {
        let width = self.corpus.width();
        let jitter = self.cfg.cluster.step_jitter;
        let w = &mut self.trainers[ti].workers[wi];
        step_compute_time(&self.nodes[w.node], plan, width, jitter, &mut w.time_rng)
    }

    /// Pick the trainers to merge this round (Algorithm 1). Empty or a
    /// single id means no merge.
    fn select_merge(&mut self) -> Vec<usize> {
        let requests: Vec<(usize, usize)> = self
            .trainers
            .iter()
            .filter(|t| t.alive)
            .map(|t| (t.id, t.requested_batch()))
            .collect();
        let policy = match self.cfg.algo.merge.policy {
            crate::config::MergeSelect::WorstByBatch => MergePolicy::WorstByBatch,
            crate::config::MergeSelect::Random => MergePolicy::Random,
        };
        check_merge_with_policy(
            &requests,
            self.cfg.algo.merge.w,
            self.cfg.algo.merge.min_trainers,
            policy,
            &mut self.rng,
        )
    }

    /// The parameter/shard consolidation of a merge (Algorithm 2), after
    /// the participants' barrier produced `t_after`. Shared by both
    /// schedulers; the ledger entry is recorded by the caller.
    fn perform_merge(&mut self, outer_t: u64, selected: &[usize], t_after: f64) -> Result<()> {
        // weighted merge over the selected trainers' parameters
        let outcome = {
            // split borrows: collect (id, b_req) first, then build the
            // mutable member list in id order
            let reqs: Vec<(usize, usize)> = selected
                .iter()
                .map(|&id| (id, self.trainers[id].requested_batch()))
                .collect();
            let mut members: Vec<(usize, usize, &mut [f32])> = Vec::new();
            // safe split of multiple &mut trainers via split_at_mut walk
            let mut rest: &mut [Trainer] = &mut self.trainers;
            let mut base = 0usize;
            let mut sorted = selected.to_vec();
            sorted.sort_unstable();
            for id in sorted {
                let local = id - base;
                let tmp = rest;
                let (head, tail) = tmp.split_at_mut(local + 1);
                let tr = &mut head[local];
                let b = reqs.iter().find(|(i, _)| *i == id).unwrap().1;
                members.push((id, b, tr.params.as_mut_slice()));
                rest = tail;
                base = id + 1;
            }
            do_merge(&mut members)
        };

        // consume the non-representative trainers
        for &dead in &outcome.removed {
            self.trainers[dead].alive = false;
        }
        // the representative keeps the union of the merged shards and its
        // own optimizer trajectory (Algorithm 2 line 9); its outer
        // momentum is reset since the parameters jumped
        let shard_refs: Vec<&crate::data::Shard> = selected
            .iter()
            .map(|&id| &self.trainers[id].shard)
            .collect();
        let merged_shard = union_shards(&shard_refs);
        let rep = outcome.representative;
        {
            // re-split among the representative's active workers (all of
            // them on a static cluster); churned-out workers get fresh
            // samplers from the merged shard when they rejoin
            let active_ix: Vec<usize> = self.trainers[rep]
                .workers
                .iter()
                .enumerate()
                .filter(|(_, w)| w.active)
                .map(|(i, _)| i)
                .collect();
            let split_ix: Vec<usize> = if active_ix.is_empty() {
                (0..self.trainers[rep].workers.len()).collect()
            } else {
                active_ix
            };
            let worker_shards = merged_shard.split(split_ix.len());
            for (&w_ix, ws) in split_ix.iter().zip(worker_shards.into_iter()) {
                self.trainers[rep].workers[w_ix].sampler =
                    crate::data::BatchSampler::new(ws, self.rng.fork(0xABCD + rep as u64));
            }
            self.trainers[rep].shard = merged_shard;
            self.trainers[rep].outer.reset();
        }

        crate::info!(
            "outer {outer_t}: merged {:?} -> representative {rep} ({} trainers left)",
            outcome.removed,
            self.live_trainers()
        );
        self.recorder.merges.push(MergeRecord {
            outer_step: outer_t,
            merged: outcome.removed.clone(),
            representative: rep,
            trainers_left: self.live_trainers(),
            virtual_time_s: t_after,
        });
        Ok(())
    }

    /// Validation loss/perplexity of `params` (fresh per-call eval RNG
    /// keyed by the outer step, so the draw is independent of when or in
    /// which order evaluations execute).
    fn compute_eval(&mut self, params: &[f32], outer_t: u64) -> Result<(f64, f64)> {
        let eb = self.engine.eval_batch();
        let width = self.val_corpus.width();
        let mut eval_rng = Rng::new(self.cfg.seed ^ 0xE7A1 ^ outer_t);
        let mut loss_acc = 0.0;
        let n = self.cfg.run.eval_batches.max(1);
        let mut buf = TokenBatch::new(eb, width);
        for _ in 0..n {
            for row in 0..eb {
                let ix = eval_rng.below(self.val_corpus.len() as u64) as usize;
                buf.row_mut(row).copy_from_slice(self.val_corpus.sequence(ix));
            }
            loss_acc += self.engine.eval_loss(params, &buf, &mut eval_rng)?;
        }
        let loss = loss_acc / n as f64;
        Ok((loss, perplexity(loss)))
    }

    fn eval_params(&mut self, params: &[f32], ti: usize, outer_t: u64) -> Result<bool> {
        let (loss, ppl) = self.compute_eval(params, outer_t)?;
        let tr = &self.trainers[ti];
        let vt = tr
            .workers
            .iter()
            .map(|w| self.clock.time(w.clock_slot))
            .fold(0.0f64, f64::max);
        self.recorder.evals.push(EvalRecord {
            global_step: tr.inner_steps_done,
            outer_step: outer_t,
            trainer: ti,
            loss,
            perplexity: ppl,
            virtual_time_s: vt,
            comm_count: self.ledger.count(),
            comm_bytes: self.ledger.total_bytes(),
        });
        Ok(self.cfg.run.target_ppl > 0.0 && ppl <= self.cfg.run.target_ppl)
    }

    /// Evaluate worker-0 parameters of trainer `ti` (mid-outer-step eval,
    /// the paper's every-10-steps cadence). Returns true if target reached.
    fn evaluate(&mut self, ti: usize, outer_t: u64) -> Result<bool> {
        let params: Vec<f32> = self.trainers[ti].workers[0].state.params.clone();
        self.eval_params(&params, ti, outer_t)
    }

    /// Evaluate the trainer's outer parameters (post-sync).
    fn evaluate_trainer_params(&mut self, ti: usize, outer_t: u64) -> Result<bool> {
        let params: Vec<f32> = self.trainers[ti].params.clone();
        self.eval_params(&params, ti, outer_t)
    }

    // ------------------------------------------------------------------
    // lockstep scheduler (reference walk)
    // ------------------------------------------------------------------

    /// One outer step of the lockstep reference walk. Returns true if the
    /// target perplexity was reached.
    pub fn step_outer(&mut self, outer_t: u64) -> Result<bool> {
        // ---- merging (Algorithm 3 lines 11-16) -------------------------
        let mc = self.cfg.algo.merge.clone();
        if mc.enabled
            && self.live_trainers() > 1
            && mc.frequency > 0
            && outer_t % mc.frequency as u64 == 0
        {
            self.maybe_merge(outer_t)?;
        }

        // ---- inner loops ------------------------------------------------
        let h = self.cfg.algo.inner_steps;
        let live: Vec<usize> = (0..self.trainers.len())
            .filter(|&i| self.trainers[i].alive)
            .collect();
        let mut hit_target = false;

        for &ti in &live {
            self.trainers[ti].broadcast_params();
            let plan = self.plan_for(ti);
            for step_h in 1..=h {
                self.inner_step(ti, outer_t, &plan)?;
                // cap on total inner steps (profiling / quick runs)
                let cap = self.cfg.run.max_inner_steps as u64;
                if cap > 0 && self.trainers[ti].inner_steps_done >= cap {
                    break;
                }
                // periodic evaluation on worker-0's live parameters
                if self.cfg.run.eval_every > 0
                    && step_h % self.cfg.run.eval_every == 0
                {
                    let reached = self.evaluate(ti, outer_t)?;
                    hit_target |= reached;
                }
            }
        }

        // ---- outer sync (Algorithm 3 lines 40-44) ------------------------
        let param_bytes = (self.engine.param_count() * 4) as u64;
        for &ti in &live {
            let m = self.trainers[ti].workers.len();
            let slots: Vec<usize> =
                self.trainers[ti].workers.iter().map(|w| w.clock_slot).collect();
            let comm_t = self.net.allreduce_time(param_bytes, m);
            let t_after = self.barrier_tracked(&slots, comm_t);
            if m > 1 {
                self.ledger.record(CommEvent {
                    kind: CommKind::OuterSync,
                    at_virtual_s: t_after,
                    bytes: (2 * (m as u64 - 1)) * param_bytes,
                    participants: m,
                    at_inner_step: self.total_samples, // N axis: samples
                });
            }
            let tr = &mut self.trainers[ti];
            tr.outer_step(&mut self.delta_scratch);
        }

        // end-of-outer-step evaluation on the trainer parameters
        for &ti in &live {
            if self.trainers[ti].alive {
                let reached = self.evaluate_trainer_params(ti, outer_t)?;
                hit_target |= reached;
            }
        }
        Ok(hit_target)
    }

    /// One inner step of every worker of trainer `ti` (lockstep walk).
    fn inner_step(&mut self, ti: usize, outer_t: u64, plan: &StepPlan) -> Result<()> {
        let lr = self
            .lr_schedule
            .lr(self.cfg.algo.lr_inner, self.trainers[ti].inner_steps_done + 1);
        let n_workers = self.trainers[ti].workers.len();

        for wi in 0..n_workers {
            let stats = self.exec_worker_step(ti, wi, plan, lr)?;

            // virtual time: accum_steps micro-steps on this worker's node
            let dt = self.step_duration(ti, wi, plan);
            let slot = self.trainers[ti].workers[wi].clock_slot;
            self.clock.advance(slot, dt);
            self.busy_s[slot] += dt;

            // adaptive-batching statistics (Algorithm 3 line 31)
            let tr = &mut self.trainers[ti];
            tr.controller.observe(&stats, plan.effective_batch());

            self.total_samples += plan.effective_batch() as u64;
            let global_step = tr.inner_steps_done + 1;
            self.recorder.steps.push(StepRecord {
                global_step,
                outer_step: outer_t,
                trainer: ti,
                worker: wi,
                batch: plan.micro_batch,
                requested_batch: tr.controller.requested(),
                accum_steps: plan.accum_steps,
                loss: stats.loss,
                grad_sq_norm: stats.grad_sq_norm,
                sigma2: stats.sigma2,
                virtual_time_s: self.clock.time(slot),
            });
        }
        self.trainers[ti].inner_steps_done += 1;
        Ok(())
    }

    /// MIT merge round (Algorithms 1-2), lockstep flavour: selection, a
    /// plain barrier over every worker of the selected trainers, then the
    /// shared consolidation.
    fn maybe_merge(&mut self, outer_t: u64) -> Result<()> {
        let selected = self.select_merge();
        if selected.len() < 2 {
            return Ok(());
        }

        // barrier every worker of the merging trainers + transfer time
        let param_bytes = (self.engine.param_count() * 4) as u64;
        let slots: Vec<usize> = selected
            .iter()
            .flat_map(|&id| self.trainers[id].workers.iter().map(|w| w.clock_slot))
            .collect();
        let bytes = (selected.len() as u64 - 1) * param_bytes;
        let t_after = self.barrier_tracked(&slots, self.net.transfer_time(bytes));
        self.ledger.record(CommEvent {
            kind: CommKind::Merge,
            at_virtual_s: t_after,
            bytes,
            participants: selected.len(),
            at_inner_step: self.total_samples,
        });
        self.perform_merge(outer_t, &selected, t_after)
    }

    // ------------------------------------------------------------------
    // event-driven scheduler
    // ------------------------------------------------------------------

    /// One outer step of the discrete-event scheduler. Returns true if
    /// the target perplexity was reached.
    ///
    /// Inner steps execute when their `StepDone` event pops — in virtual
    /// time order across all trainers and workers. Controller
    /// observations, step records and buffered evals are flushed in
    /// canonical (trainer, step, worker) order at the outer boundary,
    /// which is exactly the order the lockstep walk produces — together
    /// with per-worker RNG streams this makes the two schedulers
    /// bit-identical on static clusters.
    pub fn step_outer_event(&mut self, outer_t: u64) -> Result<bool> {
        // ---- churn: refresh worker activity, re-shard changed trainers --
        self.apply_churn()?;

        // ---- merging (same cadence and selection as lockstep) -----------
        let mc = self.cfg.algo.merge.clone();
        if mc.enabled
            && self.live_trainers() > 1
            && mc.frequency > 0
            && outer_t % mc.frequency as u64 == 0
        {
            self.maybe_merge_event(outer_t)?;
        }

        let h = self.cfg.algo.inner_steps as u64;
        let cap = self.cfg.run.max_inner_steps as u64;
        let live: Vec<usize> = (0..self.trainers.len())
            .filter(|&i| self.trainers[i].alive)
            .collect();
        let mut hit_target = false;

        // ---- per-trainer plans + bookkeeping ----------------------------
        let mut runs: Vec<Option<TrainerRun>> =
            (0..self.trainers.len()).map(|_| None).collect();
        for &ti in &live {
            self.trainers[ti].broadcast_params();
            let plan = self.plan_for(ti);
            let start_done = self.trainers[ti].inner_steps_done;
            let target = if cap == 0 {
                h
            } else {
                h.min(cap.saturating_sub(start_done).max(1))
            };
            let n_active = self.trainers[ti].workers.iter().filter(|w| w.active).count();
            let eval_worker = self.trainers[ti]
                .workers
                .iter()
                .position(|w| w.active)
                .unwrap_or(0);
            runs[ti] = Some(TrainerRun {
                plan,
                target,
                start_done,
                eval_worker,
                n_active,
                stats: Vec::with_capacity((target as usize) * n_active),
                evals: Vec::new(),
                pending: BTreeMap::new(),
            });
        }

        // ---- inner phase: serial event loop, or parallel worker chains
        //      when run.threads > 1 (bit-identical by construction —
        //      DESIGN.md §6, enforced by tests/determinism_parallel.rs)
        if self.threads > 1 {
            hit_target |= self.parallel_inner_phase(outer_t, &live, &mut runs)?;
        } else {
            hit_target |= self.event_inner_phase(outer_t, &live, &mut runs)?;
        }

        // ---- canonical flush: controller folds, step records, evals -----
        for &ti in &live {
            let mut r = match runs[ti].take() {
                Some(r) => r,
                None => continue,
            };
            if r.n_active == 0 {
                continue; // fully preempted: the trainer sat this one out
            }
            r.stats.sort_by_key(|&(s, w, _, _)| (s, w));
            for &(step, wi, ref stats, vt) in r.stats.iter() {
                let tr = &mut self.trainers[ti];
                tr.controller.observe(stats, r.plan.effective_batch());
                self.total_samples += r.plan.effective_batch() as u64;
                self.recorder.steps.push(StepRecord {
                    global_step: r.start_done + step,
                    outer_step: outer_t,
                    trainer: ti,
                    worker: wi,
                    batch: r.plan.micro_batch,
                    requested_batch: tr.controller.requested(),
                    accum_steps: r.plan.accum_steps,
                    loss: stats.loss,
                    grad_sq_norm: stats.grad_sq_norm,
                    sigma2: stats.sigma2,
                    virtual_time_s: vt,
                });
            }
            self.trainers[ti].inner_steps_done = r.start_done + r.target;
            r.evals.sort_by_key(|&(s, _)| s);
            for (_, rec) in r.evals {
                self.recorder.evals.push(rec);
            }
        }

        // ---- outer sync over active workers, in trainer order -----------
        let param_bytes = (self.engine.param_count() * 4) as u64;
        for &ti in &live {
            let members: Vec<(usize, usize)> = self.trainers[ti]
                .workers
                .iter()
                .filter(|w| w.active)
                .map(|w| (w.clock_slot, w.node))
                .collect();
            if members.is_empty() {
                continue;
            }
            let m_active = members.len();
            let slots: Vec<usize> = members.iter().map(|&(s, _)| s).collect();
            let t_start = slots
                .iter()
                .map(|&s| self.clock.time(s))
                .fold(0.0_f64, f64::max);
            let factor = self
                .scenario
                .min_bandwidth_factor(members.iter().map(|&(_, n)| n), t_start);
            let comm_t = self.net.scaled(factor).allreduce_time(param_bytes, m_active);
            let t_after = self.barrier_tracked(&slots, comm_t);
            if m_active > 1 {
                self.ledger.record(CommEvent {
                    kind: CommKind::OuterSync,
                    at_virtual_s: t_after,
                    bytes: (2 * (m_active as u64 - 1)) * param_bytes,
                    participants: m_active,
                    at_inner_step: self.total_samples,
                });
            }
            let tr = &mut self.trainers[ti];
            tr.outer_step_active(&mut self.delta_scratch);
        }

        // end-of-outer-step evaluation on the trainer parameters
        for &ti in &live {
            if self.trainers[ti].alive {
                let reached = self.evaluate_trainer_params(ti, outer_t)?;
                hit_target |= reached;
            }
        }
        Ok(hit_target)
    }

    /// The serial inner phase of one event-driven outer step: seed the
    /// queue with every active worker's first step, then consume events
    /// in virtual-time order. Returns true if a mid-loop evaluation hit
    /// the target perplexity.
    fn event_inner_phase(
        &mut self,
        outer_t: u64,
        live: &[usize],
        runs: &mut [Option<TrainerRun>],
    ) -> Result<bool> {
        let cap = self.cfg.run.max_inner_steps as u64;
        let eval_every = self.cfg.run.eval_every as u64;
        let mut hit_target = false;

        // ---- seed the queue with every active worker's first step -------
        let mut queue = EventQueue::new();
        for &ti in live {
            let plan = runs[ti].as_ref().unwrap().plan;
            for wi in 0..self.trainers[ti].workers.len() {
                if !self.trainers[ti].workers[wi].active {
                    continue;
                }
                let end = self.schedule_step_end(ti, wi, &plan);
                queue.push(end, SimEvent::StepDone { trainer: ti, worker: wi, step: 1 });
            }
        }

        // ---- consume events in virtual-time order -----------------------
        while let Some((t, ev)) = queue.pop() {
            match ev {
                SimEvent::StepDone { trainer: ti, worker: wi, step } => {
                    let slot = self.trainers[ti].workers[wi].clock_slot;
                    self.clock.advance_to(slot, t);
                    let (plan, target, start_done, eval_worker) = {
                        let r = runs[ti].as_ref().unwrap();
                        (r.plan, r.target, r.start_done, r.eval_worker)
                    };
                    let lr = self
                        .lr_schedule
                        .lr(self.cfg.algo.lr_inner, start_done + step);
                    let stats = self.exec_worker_step(ti, wi, &plan, lr)?;
                    runs[ti].as_mut().unwrap().stats.push((step, wi, stats, t));

                    // mid-loop eval bookkeeping: the eval runs once every
                    // active worker has completed this step (lockstep
                    // evaluates at the same logical point)
                    let eval_due = eval_every > 0
                        && step % eval_every == 0
                        && step <= target
                        && !(cap > 0 && start_done + step >= cap);
                    if eval_due {
                        let ready = {
                            let r = runs[ti].as_mut().unwrap();
                            let n_active = r.n_active;
                            let p = r.pending.entry(step).or_insert_with(|| PendingEval {
                                times: Vec::new(),
                                remaining: n_active,
                                params: Vec::new(),
                            });
                            p.times.push(t);
                            p.remaining -= 1;
                            p.remaining == 0
                        };
                        if wi == eval_worker {
                            let snap = self.trainers[ti].workers[wi].state.params.clone();
                            runs[ti]
                                .as_mut()
                                .unwrap()
                                .pending
                                .get_mut(&step)
                                .unwrap()
                                .params = snap;
                        }
                        if ready {
                            let pend = runs[ti]
                                .as_mut()
                                .unwrap()
                                .pending
                                .remove(&step)
                                .unwrap();
                            let vt =
                                pend.times.iter().fold(0.0f64, |acc, &x| acc.max(x));
                            let (loss, ppl) = self.compute_eval(&pend.params, outer_t)?;
                            hit_target |= self.cfg.run.target_ppl > 0.0
                                && ppl <= self.cfg.run.target_ppl;
                            let rec = EvalRecord {
                                global_step: start_done + step,
                                outer_step: outer_t,
                                trainer: ti,
                                loss,
                                perplexity: ppl,
                                virtual_time_s: vt,
                                comm_count: self.ledger.count(),
                                comm_bytes: self.ledger.total_bytes(),
                            };
                            runs[ti].as_mut().unwrap().evals.push((step, rec));
                        }
                    }

                    if step < target {
                        let end = self.schedule_step_end(ti, wi, &plan);
                        queue.push(
                            end,
                            SimEvent::StepDone { trainer: ti, worker: wi, step: step + 1 },
                        );
                    } else {
                        queue.push(t, SimEvent::SyncArrive { trainer: ti, worker: wi });
                    }
                }
                // Arrival markers: the rendezvous itself is the queue
                // draining — every active worker has posted its arrival
                // by then. (MergeArrive is handled in maybe_merge_event.)
                SimEvent::SyncArrive { .. } | SimEvent::MergeArrive { .. } => {}
            }
        }
        Ok(hit_target)
    }

    /// The parallel inner phase (the tentpole of DESIGN.md §6): between
    /// the outer-step prologue and the sync/merge rendezvous, workers are
    /// fully independent — each owns its model state, data sampler and
    /// RNG streams — so their inner-step chains fan out across
    /// `run.threads` OS threads and join at the boundary. Chain outputs
    /// are applied in canonical (trainer, worker) order and mid-loop
    /// evaluations are computed after the join, which together with the
    /// canonical flush makes the result bit-identical to the serial
    /// event loop no matter how the OS schedules the pool.
    fn parallel_inner_phase(
        &mut self,
        outer_t: u64,
        live: &[usize],
        runs: &mut [Option<TrainerRun>],
    ) -> Result<bool> {
        // ---- launch parameters, copied out before the borrow split ------
        let mut metas: Vec<ChainTask> = Vec::new();
        for &ti in live {
            let r = runs[ti].as_ref().unwrap();
            for (wi, w) in self.trainers[ti].workers.iter().enumerate() {
                if !w.active {
                    continue;
                }
                metas.push(ChainTask {
                    ti,
                    wi,
                    slot: w.clock_slot,
                    node: w.node,
                    start_time: self.clock.time(w.clock_slot),
                    busy_start: self.busy_s[w.clock_slot],
                    preempted_start: self.preempted_s[w.clock_slot],
                    plan: r.plan,
                    target: r.target,
                    start_done: r.start_done,
                    snapshot_params: wi == r.eval_worker,
                });
            }
        }

        // ---- pair tasks with exclusive worker borrows -------------------
        let ctx = ChainCtx {
            engine: self.engine.as_ref(),
            corpus: &self.corpus,
            nodes: &self.nodes,
            scenario: &self.scenario,
            lr_schedule: &self.lr_schedule,
            lr_inner: self.cfg.algo.lr_inner,
            step_jitter: self.cfg.cluster.step_jitter,
            eval_every: self.cfg.run.eval_every as u64,
            cap: self.cfg.run.max_inner_steps as u64,
            width: self.corpus.width(),
        };
        let mut tasks: Vec<(ChainTask, &mut Worker)> = Vec::with_capacity(metas.len());
        {
            let mut pending = metas.into_iter().peekable();
            for (ti, tr) in self.trainers.iter_mut().enumerate() {
                for (wi, w) in tr.workers.iter_mut().enumerate() {
                    if pending.peek().is_some_and(|m| m.ti == ti && m.wi == wi) {
                        tasks.push((pending.next().unwrap(), w));
                    }
                }
            }
        }

        // ---- fan out / join: the shared work-stealing pool, so uneven
        //      chains (stragglers, slow nodes) never strand a thread ----
        let results: Vec<Result<ChainOutput>> = crate::util::run_cells(
            self.threads,
            tasks
                .into_iter()
                .map(|(m, w)| move || run_worker_chain(ctx, m, w))
                .collect(),
        );
        let mut outputs = Vec::with_capacity(results.len());
        for r in results {
            outputs.push(r?);
        }
        // canonical application order (the scheduling order of the pool
        // must leave no trace)
        outputs.sort_by_key(|o| (o.ti, o.wi));

        // ---- apply: clocks, time accounting, step stats, snapshots ------
        let mut snaps_by_trainer: BTreeMap<usize, Vec<(u64, Vec<f32>)>> = BTreeMap::new();
        for o in outputs {
            self.clock.advance_to(o.slot, o.end_time);
            self.busy_s[o.slot] = o.busy_end;
            self.preempted_s[o.slot] = o.preempted_end;
            let r = runs[o.ti].as_mut().unwrap();
            for (step, stats, t) in o.stats {
                r.stats.push((step, o.wi, stats, t));
            }
            if !o.snaps.is_empty() {
                snaps_by_trainer.entry(o.ti).or_default().extend(o.snaps);
            }
        }

        // ---- mid-loop evaluations (deferred to the join; the eval RNG
        //      is keyed by (seed, outer_step) so timing leaves no trace) -
        let mut hit_target = false;
        for &ti in live {
            let snaps = match snaps_by_trainer.remove(&ti) {
                Some(s) => s,
                None => continue,
            };
            for (step, params) in snaps {
                let (global_step, vt) = {
                    let r = runs[ti].as_ref().unwrap();
                    let vt = r
                        .stats
                        .iter()
                        .filter(|&&(s, _, _, _)| s == step)
                        .map(|&(_, _, _, t)| t)
                        .fold(0.0f64, f64::max);
                    (r.start_done + step, vt)
                };
                let (loss, ppl) = self.compute_eval(&params, outer_t)?;
                hit_target |=
                    self.cfg.run.target_ppl > 0.0 && ppl <= self.cfg.run.target_ppl;
                let rec = EvalRecord {
                    global_step,
                    outer_step: outer_t,
                    trainer: ti,
                    loss,
                    perplexity: ppl,
                    virtual_time_s: vt,
                    comm_count: self.ledger.count(),
                    comm_bytes: self.ledger.total_bytes(),
                };
                runs[ti].as_mut().unwrap().evals.push((step, rec));
            }
        }
        Ok(hit_target)
    }

    /// Schedule the completion time of worker `wi`'s next inner step:
    /// current clock + duration, stretched by scenario stragglers and
    /// preemption windows. Accounts busy/preempted time.
    fn schedule_step_end(&mut self, ti: usize, wi: usize, plan: &StepPlan) -> f64 {
        let mut dt = self.step_duration(ti, wi, plan);
        {
            let w = &mut self.trainers[ti].workers[wi];
            dt *= self.scenario.straggler_factor(&mut w.time_rng);
        }
        let (slot, node) = {
            let w = &self.trainers[ti].workers[wi];
            (w.clock_slot, w.node)
        };
        let start = self.clock.time(slot);
        let (end, stall) = self.scenario.compute_span(node, start, dt);
        self.busy_s[slot] += dt;
        self.preempted_s[slot] += stall;
        end
    }

    /// Churn bookkeeping at an outer boundary: workers on preempted nodes
    /// sit the round out; returning workers catch their clocks up and the
    /// trainer's shard is re-split among the currently active workers
    /// (the `Shard::split` / `union_shards` machinery).
    #[allow(clippy::needless_range_loop)] // body interleaves &mut self calls
    fn apply_churn(&mut self) -> Result<()> {
        if self.scenario.is_static() {
            return Ok(());
        }
        for ti in 0..self.trainers.len() {
            if !self.trainers[ti].alive {
                continue;
            }
            // the trainer front: where its active cohort currently is; a
            // fully-preempted trainer's clocks are frozen, so fall back
            // to the global front or it would never see its window end
            let mut t_now = self.trainers[ti]
                .workers
                .iter()
                .map(|w| self.clock.time(w.clock_slot))
                .fold(0.0f64, f64::max);
            if !self.trainers[ti].workers.iter().any(|w| w.active) {
                t_now = t_now.max(self.clock.max_time());
            }
            let changed = self.trainers[ti]
                .workers
                .iter()
                .any(|w| self.scenario.node_available(w.node, t_now) != w.active);
            if !changed {
                continue;
            }
            for wi in 0..self.trainers[ti].workers.len() {
                let (node, slot, was_active) = {
                    let w = &self.trainers[ti].workers[wi];
                    (w.node, w.clock_slot, w.active)
                };
                let avail = self.scenario.node_available(node, t_now);
                if avail && !was_active {
                    // rejoin: jump to the trainer front; the gap was
                    // preemption downtime
                    let cur = self.clock.time(slot);
                    if t_now > cur {
                        self.clock.advance_to(slot, t_now);
                        self.preempted_s[slot] += t_now - cur;
                    }
                }
                self.trainers[ti].workers[wi].active = avail;
            }
            let active_ix: Vec<usize> = self.trainers[ti]
                .workers
                .iter()
                .enumerate()
                .filter(|(_, w)| w.active)
                .map(|(i, _)| i)
                .collect();
            if active_ix.is_empty() {
                crate::info!("trainer {ti}: all workers preempted; sitting this round out");
                continue;
            }
            let parts = self.trainers[ti].shard.split(active_ix.len());
            for (&w_ix, part) in active_ix.iter().zip(parts.into_iter()) {
                self.trainers[ti].workers[w_ix].sampler = crate::data::BatchSampler::new(
                    part,
                    self.rng.fork(0xC4A5 ^ ((ti as u64) << 8) ^ (w_ix as u64)),
                );
            }
            crate::debug!(
                "trainer {ti}: churn re-shard over {} active workers at t={t_now:.2}s",
                active_ix.len()
            );
        }
        Ok(())
    }

    /// MIT merge round (Algorithms 1-2), event flavour: after selection,
    /// every active worker of the selected trainers posts a `MergeArrive`
    /// at its current virtual time; the rendezvous completes when the
    /// last arrival pops, and the transfer runs at the slowest
    /// participating link's current bandwidth.
    fn maybe_merge_event(&mut self, outer_t: u64) -> Result<()> {
        let selected = self.select_merge();
        if selected.len() < 2 {
            return Ok(());
        }

        let mut queue = EventQueue::new();
        let mut slots: Vec<usize> = Vec::new();
        let mut nodes: Vec<usize> = Vec::new();
        for &id in &selected {
            for (wi, w) in self.trainers[id].workers.iter().enumerate() {
                if w.active {
                    queue.push(
                        self.clock.time(w.clock_slot),
                        SimEvent::MergeArrive { trainer: id, worker: wi },
                    );
                    slots.push(w.clock_slot);
                    nodes.push(w.node);
                }
            }
        }
        if slots.is_empty() {
            // every selected trainer is fully preempted: fall back to the
            // whole (frozen) cohort, like the lockstep barrier, instead of
            // recording a merge at virtual time ~0
            for &id in &selected {
                for w in &self.trainers[id].workers {
                    slots.push(w.clock_slot);
                    nodes.push(w.node);
                }
            }
        }
        // drain the rendezvous (arrival markers); the barrier start is the
        // last participant's clock
        while queue.pop().is_some() {}
        let t_all = slots
            .iter()
            .map(|&s| self.clock.time(s))
            .fold(0.0f64, f64::max);

        let param_bytes = (self.engine.param_count() * 4) as u64;
        let bytes = (selected.len() as u64 - 1) * param_bytes;
        let factor = self.scenario.min_bandwidth_factor(nodes.iter().copied(), t_all);
        let t_after =
            self.barrier_tracked(&slots, self.net.scaled(factor).transfer_time(bytes));
        self.ledger.record(CommEvent {
            kind: CommKind::Merge,
            at_virtual_s: t_after,
            bytes,
            participants: selected.len(),
            at_inner_step: self.total_samples,
        });
        self.perform_merge(outer_t, &selected, t_after)
    }

    /// Per-worker utilization rows from the accumulated time accounting
    /// (workers enumerate in clock-slot order).
    fn utilization_table(&self) -> Vec<UtilRecord> {
        let mut out = Vec::with_capacity(self.busy_s.len());
        for tr in &self.trainers {
            for (wi, w) in tr.workers.iter().enumerate() {
                let s = w.clock_slot;
                out.push(UtilRecord {
                    trainer: tr.id,
                    worker: wi,
                    node: w.node,
                    busy_s: self.busy_s[s],
                    wait_s: self.wait_s[s],
                    comm_s: self.comm_s[s],
                    preempted_s: self.preempted_s[s],
                });
            }
        }
        out
    }

    /// Fill the recorder's per-worker utilization table.
    fn record_utilization(&mut self) {
        self.recorder.utilization = self.utilization_table();
    }

    /// Final summary.
    pub fn result(&self) -> RunResult {
        let utils = self.utilization_table();
        let total_idle_s: f64 = utils.iter().map(|u| u.idle_s()).sum();
        let mean_utilization = if utils.is_empty() {
            0.0
        } else {
            utils.iter().map(|u| u.utilization()).sum::<f64>() / utils.len() as f64
        };
        RunResult {
            name: self.cfg.name.clone(),
            method: self.cfg.algo.method,
            best_ppl: self.recorder.best_perplexity().unwrap_or(f64::INFINITY),
            final_ppl: self.recorder.final_perplexity().unwrap_or(f64::INFINITY),
            total_inner_steps: self
                .trainers
                .iter()
                .map(|t| t.inner_steps_done)
                .max()
                .unwrap_or(0),
            total_samples: self.total_samples,
            comm_count: self.ledger.count(),
            comm_bytes: self.ledger.total_bytes(),
            virtual_time_s: self.clock.max_time(),
            trainers_left: self.live_trainers(),
            total_idle_s,
            mean_utilization,
            time_to_target: if self.cfg.run.target_ppl > 0.0 {
                self.recorder.time_to_target(self.cfg.run.target_ppl)
            } else {
                None
            },
            wall_clock_s: self.run_wall_s,
            threads: self.threads,
        }
    }
}

/// Convenience: build engine + coordinator from a config and run it.
pub fn run_experiment(cfg: Config) -> Result<RunResult> {
    let engine = crate::engine::build_engine(&cfg)?;
    let mut coord = Coordinator::new(cfg, engine)?;
    let result = coord.run()?;
    if let Some(dir) = coord.cfg.out_dir.clone() {
        let base = format!("{dir}/{}", coord.cfg.name);
        coord.recorder.write_jsonl(&format!("{base}.jsonl"))?;
        coord.recorder.write_eval_csv(&format!("{base}.csv"))?;
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn mock_cfg() -> Config {
        let mut cfg = presets::mock_default();
        cfg.algo.outer_steps = 8;
        cfg.algo.inner_steps = 15;
        cfg.algo.lr_inner = 0.15; // converge fast enough that the norm
                                  // test's request visibly grows in-test
        cfg.algo.num_trainers = 4;
        cfg.algo.workers_per_trainer = 2;
        cfg.algo.merge.frequency = 2;
        cfg.run.eval_every = 5;
        cfg
    }

    fn run_with(cfg: Config) -> (RunResult, Recorder, usize) {
        let engine = crate::engine::build_engine(&cfg).unwrap();
        let mut c = Coordinator::new(cfg, engine).unwrap();
        let r = c.run().unwrap();
        let rec = c.recorder.clone();
        (r, rec, c.live_trainers())
    }

    #[test]
    fn adloco_run_descends_and_merges() {
        let (r, rec, live) = run_with(mock_cfg());
        assert!(r.best_ppl < rec.evals.first().unwrap().perplexity);
        assert!(live < 4, "merging should consolidate trainers");
        assert!(!rec.merges.is_empty());
        assert!(r.comm_count > 0);
        assert!(r.virtual_time_s > 0.0);
    }

    #[test]
    fn adaptive_batch_grows() {
        let (_, rec, _) = run_with(mock_cfg());
        let first_req = rec.steps.first().unwrap().requested_batch;
        let last_req = rec.steps.last().unwrap().requested_batch;
        assert!(
            last_req > first_req,
            "requested batch should grow: {first_req} -> {last_req}"
        );
    }

    #[test]
    fn diloco_policy_disables_features() {
        let mut cfg = mock_cfg();
        cfg.algo.method = Method::DiLoCo;
        let resolved = resolve_policy(&cfg);
        assert!(!resolved.algo.batching.adaptive);
        assert!(!resolved.algo.merge.enabled);
        assert!(!resolved.algo.switch.enabled);

        let (r, rec, live) = run_with(cfg);
        assert_eq!(live, 4, "DiLoCo must not merge");
        assert!(rec.merges.is_empty());
        // fixed batch: every step at algo.fixed_batch
        let fixed = resolved.algo.fixed_batch;
        assert!(rec.steps.iter().all(|s| s.batch == fixed.min(16)));
        assert!(r.best_ppl.is_finite());
    }

    #[test]
    fn localsgd_uses_average_outer() {
        let mut cfg = mock_cfg();
        cfg.algo.method = Method::LocalSgd;
        let resolved = resolve_policy(&cfg);
        assert_eq!(resolved.algo.outer_opt, crate::config::OuterOptKind::Average);
        let (r, _, _) = run_with(cfg);
        assert!(r.best_ppl.is_finite());
    }

    #[test]
    fn switch_mode_engages_at_large_requests() {
        let mut cfg = mock_cfg();
        // tiny node budget + warm-started request past 2*max_batch forces
        // SwitchMode from the first plan
        for n in &mut cfg.cluster.nodes {
            n.max_batch = 2;
        }
        cfg.algo.batching.initial_batch = 10;
        cfg.algo.batching.max_request = 16; // bound accumulation depth
        cfg.algo.outer_steps = 8;
        let (_, rec, _) = run_with(cfg);
        assert!(
            rec.steps.iter().any(|s| s.accum_steps > 1),
            "switch mode never engaged"
        );
        // micro batch never exceeds the node budget
        assert!(rec.steps.iter().all(|s| s.batch <= 2));
    }

    #[test]
    fn switch_disabled_never_accumulates() {
        let mut cfg = mock_cfg();
        for n in &mut cfg.cluster.nodes {
            n.max_batch = 2;
        }
        cfg.algo.batching.max_request = 16;
        cfg.algo.switch.enabled = false;
        let (_, rec, _) = run_with(cfg);
        assert!(rec.steps.iter().all(|s| s.accum_steps == 1));
    }

    #[test]
    fn merge_preserves_param_dimension_and_counts() {
        let cfg = mock_cfg();
        let engine = crate::engine::build_engine(&cfg).unwrap();
        let mut c = Coordinator::new(cfg, engine).unwrap();
        let p = c.engine.param_count();
        for t in 1..=6u64 {
            c.step_outer(t).unwrap();
        }
        for tr in c.trainers.iter().filter(|t| t.alive) {
            assert_eq!(tr.params.len(), p);
        }
        // every merge recorded the surviving count correctly
        for m in &c.recorder.merges {
            assert!(m.trainers_left >= c.cfg.algo.merge.min_trainers);
        }
    }

    #[test]
    fn min_trainers_floor_respected() {
        let mut cfg = mock_cfg();
        cfg.algo.merge.min_trainers = 3;
        cfg.algo.merge.w = 4;
        cfg.algo.outer_steps = 10;
        let (_, _, live) = run_with(cfg);
        assert!(live >= 3, "live {live} below min_trainers floor");
    }

    #[test]
    fn comm_ledger_has_outer_syncs() {
        let cfg = mock_cfg(); // workers_per_trainer = 2 -> real syncs
        let engine = crate::engine::build_engine(&cfg).unwrap();
        let mut c = Coordinator::new(cfg, engine).unwrap();
        c.run().unwrap();
        assert!(c.ledger().count_kind(CommKind::OuterSync) > 0);
    }

    #[test]
    fn deterministic_runs() {
        let (r1, rec1, _) = run_with(mock_cfg());
        let (r2, rec2, _) = run_with(mock_cfg());
        assert_eq!(r1.comm_count, r2.comm_count);
        assert_eq!(r1.total_samples, r2.total_samples);
        assert_eq!(rec1.evals.len(), rec2.evals.len());
        for (a, b) in rec1.evals.iter().zip(rec2.evals.iter()) {
            assert!((a.perplexity - b.perplexity).abs() < 1e-9);
        }
    }

    #[test]
    fn random_merge_policy_runs_and_merges() {
        let mut cfg = mock_cfg();
        cfg.algo.merge.policy = crate::config::MergeSelect::Random;
        let (r, rec, live) = run_with(cfg);
        assert!(r.best_ppl.is_finite());
        assert!(live < 4, "random policy must still merge");
        assert!(!rec.merges.is_empty());
    }

    #[test]
    fn target_ppl_stops_early() {
        let mut cfg = mock_cfg();
        cfg.run.target_ppl = 1e14; // above the e^30 perplexity clamp => trivially reached
        let (r, _, _) = run_with(cfg);
        assert!(r.time_to_target.is_some());
        assert!(r.total_inner_steps <= 15, "should stop within first outer step");
    }

    #[test]
    fn virtual_time_monotone_in_steps() {
        let (_, rec, _) = run_with(mock_cfg());
        // per (trainer, worker) stream, virtual time must be nondecreasing
        use std::collections::HashMap;
        let mut last: HashMap<(usize, usize), f64> = HashMap::new();
        for s in &rec.steps {
            let key = (s.trainer, s.worker);
            if let Some(prev) = last.get(&key) {
                assert!(s.virtual_time_s >= *prev);
            }
            last.insert(key, s.virtual_time_s);
        }
    }

    #[test]
    fn event_scheduler_matches_lockstep_exactly() {
        // The regression anchor of the event-driven refactor: on a static
        // cluster the two schedulers must produce bit-identical ledgers,
        // records and summaries (see also tests/event_scheduler.rs for
        // the config matrix).
        let mut lock_cfg = mock_cfg();
        lock_cfg.run.scheduler = crate::config::SchedulerKind::Lockstep;
        let mut ev_cfg = mock_cfg();
        ev_cfg.run.scheduler = crate::config::SchedulerKind::Event;

        let run = |cfg: Config| {
            let engine = crate::engine::build_engine(&cfg).unwrap();
            let mut c = Coordinator::new(cfg, engine).unwrap();
            let r = c.run().unwrap();
            (r, c.recorder.clone(), c.ledger.clone())
        };
        let (ra, reca, leda) = run(lock_cfg);
        let (rb, recb, ledb) = run(ev_cfg);

        assert_eq!(leda.count(), ledb.count(), "ledger event count");
        for (a, b) in leda.events.iter().zip(ledb.events.iter()) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.bytes, b.bytes);
            assert_eq!(a.participants, b.participants);
            assert_eq!(a.at_inner_step, b.at_inner_step);
            assert_eq!(
                a.at_virtual_s.to_bits(),
                b.at_virtual_s.to_bits(),
                "ledger timestamps must be bit-identical"
            );
        }
        assert_eq!(ra.total_samples, rb.total_samples);
        assert_eq!(ra.total_inner_steps, rb.total_inner_steps);
        assert_eq!(ra.trainers_left, rb.trainers_left);
        assert_eq!(ra.best_ppl.to_bits(), rb.best_ppl.to_bits());
        assert_eq!(ra.final_ppl.to_bits(), rb.final_ppl.to_bits());
        assert_eq!(ra.virtual_time_s.to_bits(), rb.virtual_time_s.to_bits());
        assert_eq!(reca.steps.len(), recb.steps.len());
        for (a, b) in reca.steps.iter().zip(recb.steps.iter()) {
            assert_eq!((a.global_step, a.trainer, a.worker), (b.global_step, b.trainer, b.worker));
            assert_eq!(a.requested_batch, b.requested_batch);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.virtual_time_s.to_bits(), b.virtual_time_s.to_bits());
        }
        assert_eq!(reca.evals.len(), recb.evals.len());
        for (a, b) in reca.evals.iter().zip(recb.evals.iter()) {
            assert_eq!(a.perplexity.to_bits(), b.perplexity.to_bits());
            assert_eq!(a.virtual_time_s.to_bits(), b.virtual_time_s.to_bits());
        }
    }

    #[test]
    fn parallel_threads_match_serial_exactly() {
        // The parallel runtime's core invariant (DESIGN.md §6), in-module
        // smoke form; tests/determinism_parallel.rs holds the full suite.
        let mk = |threads: usize| {
            let mut cfg = mock_cfg();
            cfg.run.scheduler = crate::config::SchedulerKind::Event;
            cfg.run.threads = threads;
            cfg
        };
        let run = |cfg: Config| {
            let engine = crate::engine::build_engine(&cfg).unwrap();
            let mut c = Coordinator::new(cfg, engine).unwrap();
            let r = c.run().unwrap();
            (r, c.recorder.clone(), c.ledger.clone())
        };
        let (ra, reca, leda) = run(mk(1));
        let (rb, recb, ledb) = run(mk(4));
        assert_eq!(ra.best_ppl.to_bits(), rb.best_ppl.to_bits());
        assert_eq!(ra.virtual_time_s.to_bits(), rb.virtual_time_s.to_bits());
        assert_eq!(ra.total_idle_s.to_bits(), rb.total_idle_s.to_bits());
        assert_eq!(ra.total_samples, rb.total_samples);
        assert_eq!(leda.count(), ledb.count());
        for (a, b) in leda.events.iter().zip(ledb.events.iter()) {
            assert_eq!(a.at_virtual_s.to_bits(), b.at_virtual_s.to_bits());
        }
        assert_eq!(reca.steps.len(), recb.steps.len());
        for (a, b) in reca.steps.iter().zip(recb.steps.iter()) {
            assert_eq!((a.global_step, a.trainer, a.worker), (b.global_step, b.trainer, b.worker));
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.virtual_time_s.to_bits(), b.virtual_time_s.to_bits());
        }
        assert_eq!(reca.evals.len(), recb.evals.len());
        for (a, b) in reca.evals.iter().zip(recb.evals.iter()) {
            assert_eq!(a.perplexity.to_bits(), b.perplexity.to_bits());
        }
        assert_eq!(rb.threads, 4);
    }

    #[test]
    fn utilization_is_recorded_and_sane() {
        let (r, rec, _) = run_with(mock_cfg());
        assert_eq!(rec.utilization.len(), 8, "4 trainers x 2 workers");
        assert!(rec.utilization.iter().all(|u| u.busy_s > 0.0));
        assert!(r.mean_utilization > 0.0 && r.mean_utilization <= 1.0);
        assert!(r.total_idle_s >= 0.0);
    }

    #[test]
    fn straggler_scenario_runs_and_stretches_time() {
        let mk = |prob: f64| {
            let mut cfg = mock_cfg();
            cfg.run.scheduler = crate::config::SchedulerKind::Event;
            cfg.cluster.scenario.straggler_prob = prob;
            cfg.cluster.scenario.straggler_min = 2.0;
            cfg.cluster.scenario.straggler_max = 3.0;
            cfg
        };
        let (r0, _, _) = run_with(mk(0.0));
        let (r1, _, _) = run_with(mk(0.5));
        assert!(r1.best_ppl.is_finite());
        assert!(
            r1.virtual_time_s > r0.virtual_time_s,
            "stragglers must stretch virtual time: {} vs {}",
            r1.virtual_time_s,
            r0.virtual_time_s
        );
        assert_eq!(
            r0.total_samples, r1.total_samples,
            "stragglers change time, not the sample schedule"
        );
    }

    #[test]
    fn churn_scenario_preempts_and_rejoins() {
        let mut cfg = mock_cfg();
        cfg.algo.merge.enabled = false; // isolate churn effects
        cfg.run.scheduler = crate::config::SchedulerKind::Event;
        // node 1 is down for a mid-run stretch of virtual time
        cfg.cluster.scenario.churn.push(crate::config::ChurnWindow {
            node: 1,
            from_s: 0.3,
            until_s: 1.2,
        });
        let engine = crate::engine::build_engine(&cfg).unwrap();
        let mut c = Coordinator::new(cfg, engine).unwrap();
        let r = c.run().unwrap();
        assert!(r.best_ppl.is_finite());
        c.record_utilization();
        let preempted: f64 = c.recorder.utilization.iter().map(|u| u.preempted_s).sum();
        assert!(preempted > 0.0, "preemption must be accounted");
        // all workers are active again at the end (window long past)
        assert!(c.trainers.iter().flat_map(|t| t.workers.iter()).all(|w| w.active));
    }
}
