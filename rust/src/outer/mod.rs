//! Outer optimization (the DiLoCo bilevel structure the paper builds on):
//! workers' post-inner-loop parameters are reduced to an outer delta
//! Δ = x_prev − mean_worker(x_worker), and the trainer's parameters are
//! updated by an outer optimizer stepping along −Δ.
//!
//! Three variants, matching the paper + baselines:
//!   * `Average`  — x ← mean(x_workers)            (LocalSGD)
//!   * `Sgd`      — x ← x − lr·Δ                   (what the theorems use)
//!   * `Nesterov` — DiLoCo's default outer optimizer

use crate::config::OuterOptKind;
use crate::util::vecmath;

/// Stateful outer optimizer for one trainer.
#[derive(Clone, Debug)]
pub struct OuterOpt {
    kind: OuterOptKind,
    lr: f64,
    /// Momentum buffer (Nesterov only).
    velocity: Vec<f32>,
}

impl OuterOpt {
    /// Build an outer optimizer of `kind` over `dim` parameters.
    pub fn new(kind: OuterOptKind, lr: f64, dim: usize) -> Self {
        let velocity = match kind {
            OuterOptKind::Nesterov { .. } => vec![0.0; dim],
            _ => Vec::new(),
        };
        OuterOpt { kind, lr, velocity }
    }

    /// The configured optimizer flavour.
    pub fn kind(&self) -> OuterOptKind {
        self.kind
    }

    /// Compute Δ = x_prev − avg into `delta` (all slices same length).
    /// `workers` holds each worker's post-inner-loop parameters.
    pub fn compute_delta(x_prev: &[f32], workers: &[&[f32]], delta: &mut [f32]) {
        assert!(!workers.is_empty());
        let n = x_prev.len();
        for w in workers {
            assert_eq!(w.len(), n);
        }
        // register-blocked kernel; per-index worker order matches the old
        // serial loop, so the result is bit-identical (DESIGN.md §12)
        vecmath::delta_from_workers(x_prev, workers, delta);
    }

    /// Apply the outer update to `x` given Δ (OuterOpt step of
    /// Algorithm 3 line 43).
    pub fn step(&mut self, x: &mut [f32], delta: &[f32]) {
        assert_eq!(x.len(), delta.len());
        match self.kind {
            OuterOptKind::Average => {
                // x ← x − Δ  == mean of workers (lr ignored by design)
                vecmath::sub_assign_f32(x, delta);
            }
            OuterOptKind::Sgd => {
                vecmath::scale_sub_f32(x, delta, self.lr, false);
            }
            OuterOptKind::Nesterov { momentum } => {
                debug_assert_eq!(self.velocity.len(), x.len());
                // Nesterov lookahead: step along momentum*v + delta
                vecmath::nesterov_step_f32(x, &mut self.velocity, delta, self.lr, momentum);
            }
        }
    }

    /// Reset momentum (used when a trainer's parameters are replaced by a
    /// merge and old velocity no longer points anywhere meaningful).
    pub fn reset(&mut self) {
        self.velocity.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Momentum buffer (empty for Average/Sgd) — checkpointing.
    pub fn velocity(&self) -> &[f32] {
        &self.velocity
    }

    /// Restore the momentum buffer (checkpoint resume).
    pub fn set_velocity(&mut self, v: &[f32]) {
        if !self.velocity.is_empty() {
            assert_eq!(self.velocity.len(), v.len());
            self.velocity.copy_from_slice(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_is_prev_minus_mean() {
        let x_prev = [1.0f32, 2.0];
        let w1 = [0.0f32, 2.0];
        let w2 = [1.0f32, 0.0];
        let mut delta = [0.0f32; 2];
        OuterOpt::compute_delta(&x_prev, &[&w1, &w2], &mut delta);
        assert_eq!(delta, [0.5, 1.0]);
    }

    #[test]
    fn average_recovers_worker_mean() {
        let x_prev = [1.0f32, 2.0];
        let w1 = [0.0f32, 2.0];
        let w2 = [1.0f32, 0.0];
        let mut delta = [0.0f32; 2];
        OuterOpt::compute_delta(&x_prev, &[&w1, &w2], &mut delta);
        let mut x = x_prev;
        let mut opt = OuterOpt::new(OuterOptKind::Average, 123.0, 2);
        opt.step(&mut x, &delta);
        assert_eq!(x, [0.5, 1.0], "average must equal the worker mean");
    }

    #[test]
    fn sgd_scales_by_lr() {
        let mut x = [1.0f32];
        let delta = [0.5f32];
        let mut opt = OuterOpt::new(OuterOptKind::Sgd, 0.5, 1);
        opt.step(&mut x, &delta);
        assert!((x[0] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn nesterov_accumulates_momentum() {
        let mut x = [0.0f32];
        let delta = [1.0f32];
        let mut opt = OuterOpt::new(OuterOptKind::Nesterov { momentum: 0.9 }, 1.0, 1);
        opt.step(&mut x, &delta);
        // v=1; step = m*v + d = 1.9 -> x = -1.9
        assert!((x[0] + 1.9).abs() < 1e-6);
        opt.step(&mut x, &delta);
        // v = 0.9 + 1 = 1.9; step = 0.9*1.9 + 1 = 2.71 -> x = -4.61
        assert!((x[0] + 4.61).abs() < 1e-5);
        opt.reset();
        let mut y = [0.0f32];
        opt.step(&mut y, &delta);
        assert!((y[0] + 1.9).abs() < 1e-6, "reset clears velocity");
    }

    #[test]
    fn repeated_sgd_outer_steps_converge_on_fixed_target() {
        // With workers always reporting the optimum, outer SGD with lr<1
        // contracts toward it geometrically.
        let target = [3.0f32, -2.0];
        let mut x = [0.0f32, 0.0];
        let mut opt = OuterOpt::new(OuterOptKind::Sgd, 0.5, 2);
        let mut delta = [0.0f32; 2];
        for _ in 0..40 {
            OuterOpt::compute_delta(&x, &[&target], &mut delta);
            opt.step(&mut x, &delta);
        }
        assert!((x[0] - 3.0).abs() < 1e-3);
        assert!((x[1] + 2.0).abs() < 1e-3);
    }
}
