//! Multi-Instance Training merges (paper §4.1 + Algorithms 1-2):
//! `check_merge` selects the w trainers with the smallest requested batch
//! (small b_req = proxy for least-converged trajectory), `do_merge`
//! replaces them with their batch-size-weighted parameter average carried
//! by the strongest representative.

use crate::util::Rng;

/// Alternative policies for the ablation benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergePolicy {
    /// Paper default: w smallest requested batches.
    WorstByBatch,
    /// Random w trainers (control arm isolating the selection rule).
    Random,
}

/// Which trainers to merge this round (Algorithm 1, CHECKMERGE) — **the
/// single selection code path**: every caller, whatever the policy,
/// flows through the same edge-case and floor clamping.
///
/// Inputs are (trainer_id, requested_batch) pairs for the *live*
/// trainers. Returns the ids selected for merging (empty when no merge
/// applies). Matching the paper:  w == 0 or k <= 1 -> none;  w > k ->
/// none; `min_keep` guards the floor on the surviving trainer count (w
/// is clamped so at least `min_keep` trainers remain *after* the merge
/// collapses w into 1). The policy then picks the members: the paper's
/// w-smallest-b_req rule, or a uniform draw from `rng` (a
/// globally-ordered stream — see DESIGN.md §3.4) for the control arm.
pub fn check_merge_with_policy(
    requests: &[(usize, usize)],
    w: usize,
    min_keep: usize,
    policy: MergePolicy,
    rng: &mut Rng,
) -> Vec<usize> {
    let k = requests.len();
    if w == 0 || k <= 1 || w > k {
        return Vec::new();
    }
    // merging w trainers removes w-1; keep at least min_keep alive
    let max_removable = k.saturating_sub(min_keep.max(1));
    let w = w.min(max_removable + 1);
    if w < 2 {
        return Vec::new();
    }
    match policy {
        MergePolicy::WorstByBatch => {
            let mut order: Vec<(usize, usize)> = requests.to_vec();
            // sort ascending by b_req, tie-break on id for determinism
            order.sort_by_key(|&(id, b)| (b, id));
            order.truncate(w);
            order.into_iter().map(|(id, _)| id).collect()
        }
        MergePolicy::Random => {
            let ids: Vec<usize> = requests.iter().map(|&(id, _)| id).collect();
            let picks = rng.sample_indices(ids.len(), w);
            picks.into_iter().map(|i| ids[i]).collect()
        }
    }
}

/// Legacy entry point: the paper's worst-by-batch selection. A thin
/// wrapper over [`check_merge_with_policy`] kept for source
/// compatibility — the policy path is the one selection implementation
/// (a regression test pins the two to identical selections).
#[deprecated(note = "use check_merge_with_policy(.., MergePolicy::WorstByBatch, ..)")]
pub fn check_merge(requests: &[(usize, usize)], w: usize, min_keep: usize) -> Vec<usize> {
    // WorstByBatch never draws, so a throwaway stream changes nothing
    check_merge_with_policy(requests, w, min_keep, MergePolicy::WorstByBatch, &mut Rng::new(0))
}

/// Result of a weighted merge (Algorithm 2, DOMERGE).
#[derive(Clone, Debug)]
pub struct MergeOutcome {
    /// The trainer that carries the merged parameters forward.
    pub representative: usize,
    /// Trainers removed from the pool (everything in S except the rep).
    pub removed: Vec<usize>,
}

/// Weighted parameter average over the selected trainers:
/// x_merge = sum_j b_j x_j / sum_j b_j, written into the representative's
/// parameter buffer (the member with the largest b_req; ties -> lowest id,
/// deterministically).
///
/// `members` is a list of (trainer_id, b_req, params); all parameter
/// slices must have equal length. Returns the outcome; the caller removes
/// the consumed trainers and carries the representative's optimizer state
/// forward (Algorithm 2 line 9).
pub fn do_merge(members: &mut [(usize, usize, &mut [f32])]) -> MergeOutcome {
    let mut acc = Vec::new();
    do_merge_with_scratch(members, &mut acc)
}

/// [`do_merge`] over caller-owned f64 accumulator scratch: `acc` is
/// resized and fully re-zeroed before use, so the result is
/// bit-identical to the allocating entry point while the coordinator
/// can reuse one buffer across every merge boundary (DESIGN.md §14).
pub fn do_merge_with_scratch(
    members: &mut [(usize, usize, &mut [f32])],
    acc: &mut Vec<f64>,
) -> MergeOutcome {
    assert!(members.len() >= 2, "merge needs >= 2 members");
    let n = members[0].2.len();
    for (_, _, p) in members.iter() {
        assert_eq!(p.len(), n, "parameter length mismatch in merge");
    }
    let w_sum: f64 = members.iter().map(|&(_, b, _)| b as f64).sum();
    assert!(w_sum > 0.0, "merge weights must be positive");

    // representative: max b_req, tie-break lowest id
    let rep_pos = members
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
        .map(|(i, _)| i)
        .unwrap();

    // accumulate into f64 then write back to the representative;
    // elementwise kernels keep the per-index member order, so the result
    // is bit-identical to the old serial loops (DESIGN.md §12)
    acc.clear();
    acc.resize(n, 0.0);
    let acc = &mut acc[..n];
    for (_, b, p) in members.iter() {
        let w = *b as f64 / w_sum;
        crate::util::vecmath::weighted_add_f32(w, p, &mut acc);
    }
    let rep_id = members[rep_pos].0;
    crate::util::vecmath::write_back_f64(&acc, members[rep_pos].2);
    let removed = members
        .iter()
        .map(|&(id, _, _)| id)
        .filter(|&id| id != rep_id)
        .collect();
    MergeOutcome { representative: rep_id, removed }
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the legacy wrapper is pinned against the policy path here

    use super::*;

    /// SAT1: the deprecated wrapper and the consolidated policy path
    /// must select identically on a grid of pool shapes — one selection
    /// implementation, two entry points.
    #[test]
    fn legacy_wrapper_matches_policy_path_exactly() {
        let mut rng = Rng::new(42);
        let pools: Vec<Vec<(usize, usize)>> = vec![
            vec![],
            vec![(0, 5)],
            vec![(0, 5), (1, 3)],
            vec![(0, 50), (1, 10), (2, 30), (3, 20)],
            vec![(3, 10), (1, 10), (2, 10)],                 // ties
            vec![(7, 1), (2, 9), (5, 4), (0, 4), (9, 2)],    // sparse ids
        ];
        for reqs in &pools {
            for w in 0..=reqs.len() + 1 {
                for min_keep in 1..=reqs.len().max(1) + 1 {
                    let legacy = check_merge(reqs, w, min_keep);
                    let policy = check_merge_with_policy(
                        reqs,
                        w,
                        min_keep,
                        MergePolicy::WorstByBatch,
                        &mut rng,
                    );
                    assert_eq!(
                        legacy, policy,
                        "selection drifted for reqs={reqs:?} w={w} min_keep={min_keep}"
                    );
                }
            }
        }
    }

    #[test]
    fn check_merge_picks_w_smallest() {
        let reqs = [(0, 50), (1, 10), (2, 30), (3, 20)];
        let s = check_merge(&reqs, 2, 1);
        assert_eq!(s, vec![1, 3]);
        let s = check_merge(&reqs, 3, 1);
        assert_eq!(s, vec![1, 3, 2]);
    }

    #[test]
    fn check_merge_paper_edge_cases() {
        // w = 0 -> empty (Algorithm 1 line 3)
        assert!(check_merge(&[(0, 1), (1, 2)], 0, 1).is_empty());
        // k <= 1 -> empty
        assert!(check_merge(&[(0, 1)], 2, 1).is_empty());
        // w > k -> empty (Algorithm 1 line 10)
        assert!(check_merge(&[(0, 5), (1, 3)], 5, 1).is_empty());
    }

    #[test]
    fn min_keep_clamps_selection() {
        let reqs = [(0, 5), (1, 1), (2, 3), (3, 4)];
        // min_keep = 3: only 1 removable => w clamped to 2
        let s = check_merge(&reqs, 3, 3);
        assert_eq!(s.len(), 2);
        // min_keep = 4: nothing removable
        assert!(check_merge(&reqs, 2, 4).is_empty());
    }

    #[test]
    fn deterministic_tie_break() {
        let reqs = [(3, 10), (1, 10), (2, 10)];
        let s = check_merge(&reqs, 2, 1);
        assert_eq!(s, vec![1, 2], "ties broken by id");
    }

    #[test]
    fn do_merge_weighted_average() {
        let mut p0 = vec![1.0f32, 0.0];
        let mut p1 = vec![0.0f32, 1.0];
        let outcome = {
            let mut members = vec![(0usize, 1usize, p0.as_mut_slice()), (1, 3, p1.as_mut_slice())];
            do_merge(&mut members)
        };
        assert_eq!(outcome.representative, 1, "largest b_req is representative");
        assert_eq!(outcome.removed, vec![0]);
        // x = (1*[1,0] + 3*[0,1]) / 4 = [0.25, 0.75]
        assert!((p1[0] - 0.25).abs() < 1e-6);
        assert!((p1[1] - 0.75).abs() < 1e-6);
        // non-representative buffer untouched
        assert_eq!(p0, vec![1.0, 0.0]);
    }

    #[test]
    fn do_merge_preserves_weighted_sum() {
        // conservation: representative = weighted mean => weighted sum of
        // (params * b) is preserved by construction. Check numerically.
        let mut rng = Rng::new(5);
        let n = 64;
        let mut bufs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        let weights = [7usize, 2, 9, 4];
        let expected: Vec<f64> = (0..n)
            .map(|i| {
                bufs.iter()
                    .zip(weights.iter())
                    .map(|(p, &w)| p[i] as f64 * w as f64)
                    .sum::<f64>()
                    / 22.0
            })
            .collect();
        let outcome = {
            let mut it = bufs.iter_mut();
            let (a, b, c, d) = (
                it.next().unwrap(),
                it.next().unwrap(),
                it.next().unwrap(),
                it.next().unwrap(),
            );
            let mut members = vec![
                (0usize, weights[0], a.as_mut_slice()),
                (1, weights[1], b.as_mut_slice()),
                (2, weights[2], c.as_mut_slice()),
                (3, weights[3], d.as_mut_slice()),
            ];
            do_merge(&mut members)
        };
        assert_eq!(outcome.representative, 2);
        for i in 0..n {
            assert!((bufs[2][i] as f64 - expected[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn equal_weights_is_plain_average() {
        let mut p0 = vec![2.0f32];
        let mut p1 = vec![4.0f32];
        {
            let mut members = vec![(0usize, 5usize, p0.as_mut_slice()), (1, 5, p1.as_mut_slice())];
            let o = do_merge(&mut members);
            assert_eq!(o.representative, 0, "equal b_req tie-breaks to lowest id");
        }
        assert!((p0[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn random_policy_respects_count() {
        let mut rng = Rng::new(1);
        let reqs = [(0, 5), (1, 1), (2, 3), (3, 4)];
        let s = check_merge_with_policy(&reqs, 2, 1, MergePolicy::Random, &mut rng);
        assert_eq!(s.len(), 2);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 2);
    }
}
