//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and exposes them through the [`TrainEngine`] trait.
//!
//! Pipeline per program: HLO **text** (see aot.py for why text, not proto)
//! → `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile`
//! → cached `PjRtLoadedExecutable`. Compilation is lazy per batch-size
//! rung: a run that never requests batch 2 never pays for compiling it.
//!
//! The flat-parameter convention means every executable takes/returns the
//! same `f32[P]` params/m/v vectors; `meta.json` (parsed in
//! [`artifacts`]) describes the layout for tools that need named tensors.
//!
//! The PJRT path depends on the vendored `xla` bindings crate, which the
//! offline crate set does not always provide. It is therefore gated
//! behind the `xla` cargo feature: without it, [`XlaEngine::load`]
//! returns a descriptive error and everything else in the crate (mock
//! engine, simulator, coordinator, benches) works unchanged. The
//! transformer itself is deterministic — it ignores the `noise` streams
//! the [`TrainEngine`] contract threads through.

pub mod artifacts;

pub use artifacts::{ArtifactMeta, LadderRung, LayoutEntry};

#[cfg(feature = "xla")]
mod pjrt {
    use super::artifacts::ArtifactMeta;
    use crate::data::TokenBatch;
    use crate::engine::{ModelState, StepStats, TrainEngine};
    use crate::util::Rng;
    use anyhow::{anyhow, bail, Context, Result};
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};
    use std::sync::{Arc, Mutex};

    /// One compiled-on-demand HLO program. The executable is handed out
    /// as an `Arc` clone so callers hold it *outside* the cache lock —
    /// worker threads must not serialize on each other's PJRT execute
    /// calls (DESIGN.md §6).
    struct LazyExe {
        path: PathBuf,
        exe: Option<Arc<xla::PjRtLoadedExecutable>>,
    }

    impl LazyExe {
        fn new(path: PathBuf) -> Self {
            LazyExe { path, exe: None }
        }

        fn get(&mut self, client: &xla::PjRtClient) -> Result<Arc<xla::PjRtLoadedExecutable>> {
            if self.exe.is_none() {
                let t0 = std::time::Instant::now();
                let proto = xla::HloModuleProto::from_text_file(
                    self.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .with_context(|| format!("parsing HLO text {}", self.path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling {}", self.path.display()))?;
                crate::debug!(
                    "compiled {} in {:?}",
                    self.path.file_name().unwrap_or_default().to_string_lossy(),
                    t0.elapsed()
                );
                self.exe = Some(Arc::new(exe));
            }
            Ok(self.exe.as_ref().unwrap().clone())
        }
    }

    /// PJRT-backed training engine over one artifact profile.
    ///
    /// Thread contract (DESIGN.md §6): the lazy-compile caches and perf
    /// counters sit behind `Mutex`es so the engine can be shared by
    /// reference across the parallel runtime's worker threads; the PJRT
    /// CPU client itself is internally synchronized.
    pub struct XlaEngine {
        meta: ArtifactMeta,
        client: xla::PjRtClient,
        train: Mutex<BTreeMap<usize, LazyExe>>,
        grad: Mutex<BTreeMap<usize, LazyExe>>,
        apply: Mutex<LazyExe>,
        eval: Mutex<LazyExe>,
        ladder: Vec<usize>,
        init_params: Vec<f32>,
        /// Wall-clock spent inside PJRT execute calls (perf accounting).
        pub exec_time: Mutex<std::time::Duration>,
        /// Number of PJRT execute calls issued.
        pub exec_calls: Mutex<u64>,
    }

    // SAFETY: every mutable member (lazy-compile caches, perf counters)
    // is Mutex-guarded above; the raw PJRT client/executable handles are
    // only used through the thread-safe PJRT C API.
    unsafe impl Send for XlaEngine {}
    unsafe impl Sync for XlaEngine {}

    impl XlaEngine {
        /// Load `artifacts_dir/profile` (meta.json + HLO files + init params).
        pub fn load(artifacts_dir: &str, profile: &str) -> Result<XlaEngine> {
            let dir = Path::new(artifacts_dir).join(profile);
            let meta = ArtifactMeta::load(&dir.join("meta.json"))
                .with_context(|| format!("loading artifact profile {}", dir.display()))?;

            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;

            let mut train = BTreeMap::new();
            for rung in &meta.ladder {
                train.insert(rung.batch, LazyExe::new(dir.join(&rung.file)));
            }
            let mut grad = BTreeMap::new();
            for rung in &meta.grad_steps {
                grad.insert(rung.batch, LazyExe::new(dir.join(&rung.file)));
            }
            let ladder: Vec<usize> = meta.ladder.iter().map(|r| r.batch).collect();

            let init_path = dir.join(&meta.init_params_file);
            let raw = std::fs::read(&init_path)
                .with_context(|| format!("reading {}", init_path.display()))?;
            if raw.len() != meta.param_count * 4 {
                bail!(
                    "init params size {} != 4 * param_count {}",
                    raw.len(),
                    meta.param_count
                );
            }
            let init_params: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();

            Ok(XlaEngine {
                client,
                train: Mutex::new(train),
                grad: Mutex::new(grad),
                apply: Mutex::new(LazyExe::new(dir.join(&meta.apply_update_file))),
                eval: Mutex::new(LazyExe::new(dir.join(&meta.eval_file))),
                ladder,
                init_params,
                meta,
                exec_time: Mutex::new(std::time::Duration::ZERO),
                exec_calls: Mutex::new(0),
            })
        }

        /// Parsed `meta.json` of the loaded profile.
        pub fn meta(&self) -> &ArtifactMeta {
            &self.meta
        }

        /// Force-compile every program (used by benches to exclude compile
        /// time from measurements).
        pub fn warmup(&self) -> Result<()> {
            for (_, exe) in self.train.lock().unwrap().iter_mut() {
                exe.get(&self.client)?;
            }
            for (_, exe) in self.grad.lock().unwrap().iter_mut() {
                exe.get(&self.client)?;
            }
            self.apply.lock().unwrap().get(&self.client)?;
            self.eval.lock().unwrap().get(&self.client)?;
            Ok(())
        }

        /// Upload a flat f32 slice straight into a device buffer — one copy,
        /// no intermediate `Literal` materialization (perf: see
        /// EXPERIMENTS.md §Perf; the params/m/v vectors dominate per-step
        /// transfer volume).
        fn upload_f32(&self, data: &[f32]) -> Result<xla::PjRtBuffer> {
            self.client
                .buffer_from_host_buffer(data, &[data.len()], None)
                .map_err(|e| anyhow!("upload f32[{}]: {e:?}", data.len()))
        }

        fn upload_tokens(&self, batch: &TokenBatch) -> Result<xla::PjRtBuffer> {
            let want_width = self.meta.seq_len + 1;
            if batch.width != want_width {
                bail!("token width {} != seq_len+1 {}", batch.width, want_width);
            }
            self.client
                .buffer_from_host_buffer(&batch.tokens, &[batch.batch, batch.width], None)
                .map_err(|e| anyhow!("upload tokens: {e:?}"))
        }

        fn upload_scalar(&self, v: f32) -> Result<xla::PjRtBuffer> {
            self.upload_f32(&[v])
        }

        fn execute(
            &self,
            exe: &xla::PjRtLoadedExecutable,
            args: &[xla::PjRtBuffer],
        ) -> Result<Vec<xla::Literal>> {
            let t0 = std::time::Instant::now();
            let result = exe
                .execute_b::<&xla::PjRtBuffer>(&args.iter().collect::<Vec<_>>())
                .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            *self.exec_time.lock().unwrap() += t0.elapsed();
            *self.exec_calls.lock().unwrap() += 1;
            result.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
        }
    }

    fn read_f32_into(lit: &xla::Literal, out: &mut [f32]) -> Result<()> {
        lit.copy_raw_to(out).map_err(|e| anyhow!("copy_raw_to: {e:?}"))
    }

    fn read_scalar(lit: &xla::Literal) -> Result<f64> {
        let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(v.first().copied().unwrap_or(f32::NAN) as f64)
    }

    impl TrainEngine for XlaEngine {
        fn name(&self) -> String {
            format!(
                "xla({}, P={}, seq={})",
                self.meta.profile, self.meta.param_count, self.meta.seq_len
            )
        }

        fn param_count(&self) -> usize {
            self.meta.param_count
        }

        fn init_state(&self, seed: u64) -> ModelState {
            // Base initialization comes from the artifact (deterministic,
            // shared); per-trainer independence (MIT §4.1) is a small seeded
            // jitter on top — same architecture, different basin.
            let mut params = self.init_params.clone();
            if seed != 0 {
                let mut rng = crate::util::Rng::new(seed);
                for p in params.iter_mut() {
                    *p += rng.normal_ms(0.0, 0.01) as f32;
                }
            }
            ModelState::zeros_like(params)
        }

        fn supported_batches(&self) -> &[usize] {
            &self.ladder
        }

        fn eval_batch(&self) -> usize {
            self.meta.eval_batch
        }

        fn train_step(
            &self,
            state: &mut ModelState,
            lr: f64,
            batch: &TokenBatch,
            _noise: &mut Rng, // PJRT programs are deterministic
        ) -> Result<StepStats> {
            // fetch (compiling at most once) under the lock, execute
            // outside it — concurrent worker threads overlap here
            let exe = {
                let mut map = self.train.lock().unwrap();
                map.get_mut(&batch.batch)
                    .ok_or_else(|| anyhow!("no train executable for batch {}", batch.batch))?
                    .get(&self.client)?
            };
            let exe_args = [
                self.upload_f32(&state.params)?,
                self.upload_f32(&state.m)?,
                self.upload_f32(&state.v)?,
                self.upload_scalar((state.step + 1) as f32)?,
                self.upload_scalar(lr as f32)?,
                self.upload_tokens(batch)?,
            ];
            let outs = self.execute(&exe, &exe_args)?;
            if outs.len() != 7 {
                bail!("train_step returned {} outputs, want 7", outs.len());
            }
            read_f32_into(&outs[0], &mut state.params)?;
            read_f32_into(&outs[1], &mut state.m)?;
            read_f32_into(&outs[2], &mut state.v)?;
            state.step += 1;
            Ok(StepStats {
                loss: read_scalar(&outs[3])?,
                grad_sq_norm: read_scalar(&outs[4])?,
                sigma2: read_scalar(&outs[5])?,
                ip_var: read_scalar(&outs[6])?,
            })
        }

        fn grad_step(
            &self,
            params: &[f32],
            batch: &TokenBatch,
            grad_out: &mut [f32],
            _noise: &mut Rng,
        ) -> Result<StepStats> {
            let exe = {
                let mut map = self.grad.lock().unwrap();
                map.get_mut(&batch.batch)
                    .ok_or_else(|| {
                        anyhow!("no grad_step executable for batch {}", batch.batch)
                    })?
                    .get(&self.client)?
            };
            let exe_args = [self.upload_f32(params)?, self.upload_tokens(batch)?];
            let outs = self.execute(&exe, &exe_args)?;
            if outs.len() != 5 {
                bail!("grad_step returned {} outputs, want 5", outs.len());
            }
            read_f32_into(&outs[0], grad_out)?;
            Ok(StepStats {
                loss: read_scalar(&outs[1])?,
                grad_sq_norm: read_scalar(&outs[2])?,
                sigma2: read_scalar(&outs[3])?,
                ip_var: read_scalar(&outs[4])?,
            })
        }

        fn apply_update(&self, state: &mut ModelState, lr: f64, grad: &[f32]) -> Result<()> {
            let exe_args = [
                self.upload_f32(&state.params)?,
                self.upload_f32(&state.m)?,
                self.upload_f32(&state.v)?,
                self.upload_scalar((state.step + 1) as f32)?,
                self.upload_scalar(lr as f32)?,
                self.upload_f32(grad)?,
            ];
            let exe = self.apply.lock().unwrap().get(&self.client)?;
            let outs = self.execute(&exe, &exe_args)?;
            if outs.len() != 3 {
                bail!("apply_update returned {} outputs, want 3", outs.len());
            }
            read_f32_into(&outs[0], &mut state.params)?;
            read_f32_into(&outs[1], &mut state.m)?;
            read_f32_into(&outs[2], &mut state.v)?;
            state.step += 1;
            Ok(())
        }

        fn eval_loss(
            &self,
            params: &[f32],
            batch: &TokenBatch,
            _noise: &mut Rng,
        ) -> Result<f64> {
            if batch.batch != self.meta.eval_batch {
                bail!(
                    "eval compiled for batch {}, got {}",
                    self.meta.eval_batch,
                    batch.batch
                );
            }
            let exe = self.eval.lock().unwrap().get(&self.client)?;
            let exe_args = [self.upload_f32(params)?, self.upload_tokens(batch)?];
            let outs = self.execute(&exe, &exe_args)?;
            read_scalar(&outs[0])
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::data::TokenBatch;
        use crate::util::Rng;

        fn artifacts_present() -> bool {
            Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny/meta.json")).exists()
        }

        fn load_tiny() -> XlaEngine {
            XlaEngine::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"), "tiny").unwrap()
        }

        fn random_batch(rng: &mut Rng, b: usize, width: usize, vocab: i64) -> TokenBatch {
            let mut tb = TokenBatch::new(b, width);
            for t in tb.tokens.iter_mut() {
                *t = rng.range(0, vocab) as i32;
            }
            tb
        }

        #[test]
        fn loads_meta_and_params() {
            if !artifacts_present() {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
            let e = load_tiny();
            assert_eq!(e.param_count(), e.meta().param_count);
            assert!(!e.supported_batches().is_empty());
            let st = e.init_state(0);
            assert_eq!(st.params.len(), e.param_count());
            // jittered init differs from base but stays close
            let st2 = e.init_state(42);
            assert_ne!(st.params, st2.params);
        }

        #[test]
        fn train_step_descends_and_is_deterministic() {
            if !artifacts_present() {
                return;
            }
            let e = load_tiny();
            let width = e.meta().seq_len + 1;
            let mut rng = Rng::new(0);
            let mut noise = Rng::new(1);
            let tb = random_batch(&mut rng, 4, width, 256);

            let mut s1 = e.init_state(0);
            let mut s2 = e.init_state(0);
            let r1 = e.train_step(&mut s1, 4e-4, &tb, &mut noise).unwrap();
            let r2 = e.train_step(&mut s2, 4e-4, &tb, &mut noise).unwrap();
            assert_eq!(s1.params, s2.params, "train_step must be deterministic");
            assert!((r1.loss - r2.loss).abs() < 1e-9);
            assert!((r1.loss - (256f64).ln()).abs() < 1.0, "init loss ~ ln(vocab)");
            assert!(r1.grad_sq_norm > 0.0);
            assert!(r1.sigma2 > 0.0);

            // overfit a single batch for a few steps
            let first = r1.loss;
            let mut last = first;
            for _ in 0..10 {
                last = e.train_step(&mut s1, 1e-3, &tb, &mut noise).unwrap().loss;
            }
            assert!(last < first, "loss {first} -> {last}");
        }

        #[test]
        fn grad_apply_matches_train_step() {
            if !artifacts_present() {
                return;
            }
            let e = load_tiny();
            let width = e.meta().seq_len + 1;
            let bmax = e.meta().grad_step_batch;
            let mut rng = Rng::new(1);
            let mut noise = Rng::new(2);
            let tb = random_batch(&mut rng, bmax, width, 256);

            let mut s1 = e.init_state(0);
            let mut s2 = e.init_state(0);
            let r1 = e.train_step(&mut s1, 4e-4, &tb, &mut noise).unwrap();

            let mut grad = vec![0.0f32; e.param_count()];
            let r2 = e.grad_step(&s2.params, &tb, &mut grad, &mut noise).unwrap();
            e.apply_update(&mut s2, 4e-4, &grad).unwrap();

            assert!((r1.loss - r2.loss).abs() < 1e-5);
            let max_diff = s1
                .params
                .iter()
                .zip(s2.params.iter())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 1e-5, "grad+apply vs train_step diff {max_diff}");
        }

        #[test]
        fn eval_loss_sane() {
            if !artifacts_present() {
                return;
            }
            let e = load_tiny();
            let width = e.meta().seq_len + 1;
            let eb = e.eval_batch();
            let mut rng = Rng::new(2);
            let mut noise = Rng::new(3);
            let tb = random_batch(&mut rng, eb, width, 256);
            let st = e.init_state(0);
            let loss = e.eval_loss(&st.params, &tb, &mut noise).unwrap();
            assert!((loss - (256f64).ln()).abs() < 1.0, "eval loss {loss}");
        }

        #[test]
        fn rejects_wrong_shapes() {
            if !artifacts_present() {
                return;
            }
            let e = load_tiny();
            let mut noise = Rng::new(0);
            let mut st = e.init_state(0);
            // unsupported batch size
            let tb = TokenBatch::new(3, e.meta().seq_len + 1);
            assert!(e.train_step(&mut st, 1e-3, &tb, &mut noise).is_err());
            // wrong token width
            let tb = TokenBatch::new(4, 5);
            assert!(e.train_step(&mut st, 1e-3, &tb, &mut noise).is_err());
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::XlaEngine;

#[cfg(not(feature = "xla"))]
mod stub {
    use super::artifacts::ArtifactMeta;
    use crate::data::TokenBatch;
    use crate::engine::{ModelState, StepStats, TrainEngine};
    use crate::util::Rng;
    use anyhow::{bail, Result};

    /// Placeholder for the PJRT engine when the crate is built without the
    /// `xla` feature. [`XlaEngine::load`] always errors, so no instance
    /// ever exists and the trait methods are unreachable.
    pub struct XlaEngine {
        never: std::convert::Infallible,
    }

    impl XlaEngine {
        /// Always errors: the crate was built without the `xla` feature.
        pub fn load(artifacts_dir: &str, profile: &str) -> Result<XlaEngine> {
            bail!(
                "cannot load artifact profile {profile:?} from {artifacts_dir:?}: \
                 adloco was built without the `xla` feature, so the PJRT engine \
                 is unavailable (use a mock preset, or rebuild with \
                 `--features xla` and the vendored xla dependency)"
            )
        }

        /// Unreachable (no stub instance can exist).
        pub fn meta(&self) -> &ArtifactMeta {
            match self.never {}
        }

        /// Unreachable (no stub instance can exist).
        pub fn warmup(&self) -> Result<()> {
            match self.never {}
        }
    }

    impl TrainEngine for XlaEngine {
        fn name(&self) -> String {
            match self.never {}
        }

        fn param_count(&self) -> usize {
            match self.never {}
        }

        fn init_state(&self, _seed: u64) -> ModelState {
            match self.never {}
        }

        fn supported_batches(&self) -> &[usize] {
            match self.never {}
        }

        fn eval_batch(&self) -> usize {
            match self.never {}
        }

        fn train_step(
            &self,
            _state: &mut ModelState,
            _lr: f64,
            _batch: &TokenBatch,
            _noise: &mut Rng,
        ) -> Result<StepStats> {
            match self.never {}
        }

        fn grad_step(
            &self,
            _params: &[f32],
            _batch: &TokenBatch,
            _grad_out: &mut [f32],
            _noise: &mut Rng,
        ) -> Result<StepStats> {
            match self.never {}
        }

        fn apply_update(&self, _state: &mut ModelState, _lr: f64, _grad: &[f32]) -> Result<()> {
            match self.never {}
        }

        fn eval_loss(&self, _params: &[f32], _batch: &TokenBatch, _noise: &mut Rng) -> Result<f64> {
            match self.never {}
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaEngine;
