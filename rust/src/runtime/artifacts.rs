//! `meta.json` parsing: the contract between `python/compile/aot.py` and
//! the Rust runtime. Fails loudly on any missing/odd field — a silently
//! misread artifact layout corrupts every downstream experiment.

use crate::util::JsonValue;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// One entry of the flat-parameter layout table.
#[derive(Clone, Debug, PartialEq)]
pub struct LayoutEntry {
    /// Tensor name (e.g. `layers.0.attn.wq`).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Offset into the flat parameter vector.
    pub offset: usize,
}

impl LayoutEntry {
    /// Element count of the tensor.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One batch-size rung of the AOT ladder.
#[derive(Clone, Debug, PartialEq)]
pub struct LadderRung {
    /// Compiled batch size.
    pub batch: usize,
    /// Variance-statistic chunk count the program was lowered with.
    pub chunks: usize,
    /// HLO text file (relative to the profile directory).
    pub file: String,
}

/// Parsed artifact profile metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Profile name (e.g. "tiny").
    pub profile: String,
    /// Flat parameter vector length.
    pub param_count: usize,
    /// Vocabulary size the model was lowered with.
    pub vocab: usize,
    /// Model width.
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Attention head count.
    pub n_heads: usize,
    /// Sequence length.
    pub seq_len: usize,
    /// Named-tensor layout of the flat vector.
    pub layout: Vec<LayoutEntry>,
    /// Compiled train_step batch ladder.
    pub ladder: Vec<LadderRung>,
    /// Batch size of the top grad_step program.
    pub grad_step_batch: usize,
    /// HLO file of the top grad_step program.
    pub grad_step_file: String,
    /// Per-rung grad_step programs (SwitchMode at any node budget).
    /// Falls back to just the top rung for older artifact bundles.
    pub grad_steps: Vec<LadderRung>,
    /// HLO file of the apply_update program.
    pub apply_update_file: String,
    /// Batch size the eval program was compiled for.
    pub eval_batch: usize,
    /// HLO file of the eval program.
    pub eval_file: String,
    /// Raw little-endian f32 file holding the shared initialization.
    pub init_params_file: String,
}

impl ArtifactMeta {
    /// Load and validate `meta.json` from `path`.
    pub fn load(path: &Path) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = JsonValue::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&v)
    }

    /// Build from a parsed `meta.json` document.
    pub fn from_json(v: &JsonValue) -> Result<ArtifactMeta> {
        let req_usize = |obj: &JsonValue, key: &str| -> Result<usize> {
            obj.get(key)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("meta.json: missing/invalid {key}"))
        };
        let req_str = |obj: &JsonValue, key: &str| -> Result<String> {
            obj.get(key)
                .and_then(|x| x.as_str())
                .map(str::to_string)
                .ok_or_else(|| anyhow!("meta.json: missing/invalid {key}"))
        };

        let model = v.get("model").ok_or_else(|| anyhow!("meta.json: missing model"))?;

        let layout_obj = v.get("layout").ok_or_else(|| anyhow!("meta.json: missing layout"))?;
        let entries = layout_obj
            .get("entries")
            .and_then(|x| x.as_array())
            .ok_or_else(|| anyhow!("meta.json: layout.entries"))?;
        let mut layout = Vec::with_capacity(entries.len());
        for e in entries {
            layout.push(LayoutEntry {
                name: req_str(e, "name")?,
                shape: e
                    .get("shape")
                    .and_then(|x| x.as_array())
                    .ok_or_else(|| anyhow!("layout entry shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
                    .collect::<Result<Vec<_>>>()?,
                offset: req_usize(e, "offset")?,
            });
        }

        let ladder_arr = v
            .get("ladder")
            .and_then(|x| x.as_array())
            .ok_or_else(|| anyhow!("meta.json: ladder"))?;
        let mut ladder = Vec::with_capacity(ladder_arr.len());
        for r in ladder_arr {
            ladder.push(LadderRung {
                batch: req_usize(r, "batch")?,
                chunks: req_usize(r, "chunks")?,
                file: req_str(r, "file")?,
            });
        }
        if ladder.is_empty() {
            bail!("meta.json: empty ladder");
        }
        if !ladder.windows(2).all(|w| w[0].batch < w[1].batch) {
            bail!("meta.json: ladder must be strictly ascending");
        }

        let grad = v.get("grad_step").ok_or_else(|| anyhow!("meta.json: grad_step"))?;
        let mut grad_steps = Vec::new();
        if let Some(arr) = v.get("grad_steps").and_then(|x| x.as_array()) {
            for r in arr {
                grad_steps.push(LadderRung {
                    batch: req_usize(r, "batch")?,
                    chunks: req_usize(r, "chunks")?,
                    file: req_str(r, "file")?,
                });
            }
        }
        if grad_steps.is_empty() {
            grad_steps.push(LadderRung {
                batch: req_usize(grad, "batch")?,
                chunks: req_usize(grad, "chunks")?,
                file: req_str(grad, "file")?,
            });
        }
        let eval = v.get("eval").ok_or_else(|| anyhow!("meta.json: eval"))?;
        let init = v.get("init_params").ok_or_else(|| anyhow!("meta.json: init_params"))?;

        let meta = ArtifactMeta {
            profile: req_str(v, "profile")?,
            param_count: req_usize(v, "param_count")?,
            vocab: req_usize(model, "vocab")?,
            d_model: req_usize(model, "d_model")?,
            n_layers: req_usize(model, "n_layers")?,
            n_heads: req_usize(model, "n_heads")?,
            seq_len: req_usize(model, "seq_len")?,
            layout,
            ladder,
            grad_step_batch: req_usize(grad, "batch")?,
            grad_step_file: req_str(grad, "file")?,
            grad_steps,
            apply_update_file: req_str(
                v.get("apply_update").ok_or_else(|| anyhow!("meta.json: apply_update"))?,
                "file",
            )?,
            eval_batch: req_usize(eval, "batch")?,
            eval_file: req_str(eval, "file")?,
            init_params_file: req_str(init, "file")?,
        };
        meta.validate()?;
        Ok(meta)
    }

    fn validate(&self) -> Result<()> {
        // layout must tile [0, param_count) contiguously
        let mut off = 0usize;
        for e in &self.layout {
            if e.offset != off {
                bail!("layout entry {} offset {} != expected {off}", e.name, e.offset);
            }
            off += e.numel();
        }
        if off != self.param_count {
            bail!("layout covers {off} params, meta says {}", self.param_count);
        }
        for r in &self.ladder {
            if r.batch == 0 || r.batch % r.chunks != 0 {
                bail!("ladder rung {:?} invalid", r);
            }
        }
        if self.d_model % self.n_heads != 0 {
            bail!("d_model {} not divisible by n_heads {}", self.d_model, self.n_heads);
        }
        Ok(())
    }

    /// Look up a named tensor's layout entry.
    pub fn entry(&self, name: &str) -> Option<&LayoutEntry> {
        self.layout.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal_meta_json() -> String {
        r#"{
          "profile": "t",
          "param_count": 10,
          "model": {"vocab": 8, "d_model": 4, "n_layers": 1, "n_heads": 2, "seq_len": 3},
          "layout": {"total": 10, "entries": [
             {"name": "a", "shape": [2, 3], "offset": 0},
             {"name": "b", "shape": [4], "offset": 6}
          ]},
          "ladder": [
            {"batch": 1, "chunks": 1, "file": "t1.hlo.txt"},
            {"batch": 4, "chunks": 2, "file": "t4.hlo.txt"}
          ],
          "grad_step": {"batch": 4, "chunks": 2, "file": "g.hlo.txt"},
          "apply_update": {"file": "a.hlo.txt"},
          "eval": {"batch": 2, "file": "e.hlo.txt"},
          "init_params": {"file": "init.bin", "seed": 1, "sha256": "x"}
        }"#
        .to_string()
    }

    #[test]
    fn parses_minimal() {
        let v = JsonValue::parse(&minimal_meta_json()).unwrap();
        let m = ArtifactMeta::from_json(&v).unwrap();
        assert_eq!(m.profile, "t");
        assert_eq!(m.param_count, 10);
        assert_eq!(m.layout.len(), 2);
        assert_eq!(m.entry("b").unwrap().offset, 6);
        assert_eq!(m.ladder[1].batch, 4);
        assert_eq!(m.grad_step_batch, 4);
        assert_eq!(m.eval_batch, 2);
    }

    #[test]
    fn rejects_gap_in_layout() {
        let text = minimal_meta_json().replace("\"offset\": 6", "\"offset\": 7");
        let v = JsonValue::parse(&text).unwrap();
        assert!(ArtifactMeta::from_json(&v).is_err());
    }

    #[test]
    fn rejects_unsorted_ladder() {
        let text = minimal_meta_json()
            .replace("{\"batch\": 1, \"chunks\": 1, \"file\": \"t1.hlo.txt\"}",
                     "{\"batch\": 8, \"chunks\": 1, \"file\": \"t8.hlo.txt\"}");
        let v = JsonValue::parse(&text).unwrap();
        assert!(ArtifactMeta::from_json(&v).is_err());
    }

    #[test]
    fn rejects_missing_field() {
        let text = minimal_meta_json().replace("\"param_count\": 10,", "");
        let v = JsonValue::parse(&text).unwrap();
        assert!(ArtifactMeta::from_json(&v).is_err());
    }

    #[test]
    fn parses_real_artifact_if_present() {
        let p = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny/meta.json"));
        if p.exists() {
            let m = ArtifactMeta::load(p).unwrap();
            assert_eq!(m.profile, "tiny");
            assert_eq!(m.vocab, 256);
            assert!(m.param_count > 100_000);
            assert_eq!(m.entry("embed").unwrap().offset, 0);
        }
    }
}
