//! Parameter sweeps: run the same experiment across a grid of one
//! config knob (optionally crossed with methods) and tabulate the
//! results — the workhorse behind the design-choice ablations DESIGN.md
//! calls out (η sensitivity, merge frequency, switch multiplier, ...).

use crate::config::{Config, Method};
use crate::coordinator::{resolve_policy, Coordinator, RunResult};
use crate::engine::build_engine;
use anyhow::{Context, Result};

/// One sweep cell result.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub value: String,
    pub method: Method,
    pub result: RunResult,
    pub mean_batch: f64,
}

/// Run `base` once per (value, method) with `param=value` applied.
pub fn run_sweep(
    base: &Config,
    param: &str,
    values: &[String],
    methods: &[Method],
) -> Result<Vec<SweepRow>> {
    let mut rows = Vec::new();
    for value in values {
        for &method in methods {
            let mut cfg = base.clone();
            cfg.algo.method = method;
            cfg.name = format!("{}_{}={}_{}", base.name, param, value, method.as_str());
            cfg.apply_override(&format!("{param}={value}"))
                .with_context(|| format!("sweep value {value:?}"))?;
            let cfg = resolve_policy(&cfg);
            cfg.validate()?;
            crate::info!("sweep: {}", cfg.name);
            let engine = build_engine(&cfg)?;
            let mut coord = Coordinator::new(cfg, engine)?;
            let result = coord.run()?;
            rows.push(SweepRow {
                value: value.clone(),
                method,
                result,
                mean_batch: coord.recorder.mean_batch(),
            });
        }
    }
    Ok(rows)
}

/// Render sweep rows as an aligned text table (also used by the CLI).
pub fn format_table(param: &str, rows: &[SweepRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:<10} {:>10} {:>10} {:>8} {:>12} {:>10} {:>11}\n",
        param, "method", "best_ppl", "final_ppl", "comms", "samples", "vtime_s", "mean_batch"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:<10} {:>10.4} {:>10.4} {:>8} {:>12} {:>10.3} {:>11.1}\n",
            r.value,
            r.method.as_str(),
            r.result.best_ppl,
            r.result.final_ppl,
            r.result.comm_count,
            r.result.total_samples,
            r.result.virtual_time_s,
            r.mean_batch,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn eta_sweep_runs_and_orders() {
        let mut base = presets::quick();
        base.algo.outer_steps = 2;
        base.algo.inner_steps = 5;
        let rows = run_sweep(
            &base,
            "algo.batching.eta",
            &["0.4".into(), "1.6".into()],
            &[Method::AdLoCo],
        )
        .unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.result.best_ppl.is_finite());
        }
        // smaller eta => stricter test => larger requested batches
        // (can only be checked weakly on a short run: at minimum the
        // sweep must produce distinct configurations)
        assert_ne!(rows[0].value, rows[1].value);
        let table = format_table("eta", &rows);
        assert!(table.contains("0.4") && table.contains("1.6"));
    }

    #[test]
    fn sweep_crosses_methods() {
        let mut base = presets::quick();
        base.algo.outer_steps = 2;
        base.algo.inner_steps = 4;
        let rows = run_sweep(
            &base,
            "algo.inner_steps",
            &["3".into()],
            &[Method::AdLoCo, Method::DiLoCo],
        )
        .unwrap();
        assert_eq!(rows.len(), 2);
        assert_ne!(rows[0].method, rows[1].method);
    }

    #[test]
    fn bad_param_is_error() {
        let base = presets::quick();
        assert!(run_sweep(&base, "algo.method", &["bogus".into()], &[Method::AdLoCo]).is_err());
    }
}
