//! Parameter sweeps: run the same experiment across a grid of one
//! config knob (optionally crossed with methods) and tabulate the
//! results — the workhorse behind the design-choice ablations DESIGN.md
//! calls out (η sensitivity, merge frequency, switch multiplier, ...).
//!
//! Parallelism (DESIGN.md §6): cells are independent experiments, so
//! [`run_sweep_jobs`] fans them out across OS threads. Three rules keep
//! the grid deterministic regardless of `jobs`:
//!
//! 1. **cell configs are built up front, in grid order** (errors surface
//!    at the same cell the serial walk would hit first);
//! 2. **seeds are derived, not improvised**: every cell at one sweep
//!    value runs at `derive_seed(base.seed, "<param>=<value>")` — a pure
//!    function of the base seed and the value, independent of which
//!    thread executes the cell and of the grid's enumeration order.
//!    Method arms at the same value deliberately share that seed, so
//!    the central comparison (AdLoCo vs the baselines) stays
//!    seed-paired: same data order, same noise draws, algorithm effect
//!    unconfounded by seed variance;
//! 3. **results collect into their grid index** (ordered collection), so
//!    the returned rows never depend on completion order.

use crate::config::{Config, Method};
use crate::coordinator::{resolve_policy, Coordinator, RunResult};
use crate::engine::build_engine;
use crate::util::{derive_seed, run_cells};
use anyhow::{Context, Result};

/// One sweep cell result.
#[derive(Clone, Debug)]
pub struct SweepRow {
    /// The swept parameter's value for this cell (verbatim CLI string).
    pub value: String,
    /// Coordination method of this cell.
    pub method: Method,
    /// Run summary of the cell.
    pub result: RunResult,
    /// Mean executed batch over the cell's steps.
    pub mean_batch: f64,
    /// Host wall-clock seconds the cell took (perf reporting only).
    pub wall_s: f64,
}

/// Run `base` once per (value, method) with `param=value` applied,
/// serially in grid order. Equivalent to `run_sweep_jobs(.., 1)`.
pub fn run_sweep(
    base: &Config,
    param: &str,
    values: &[String],
    methods: &[Method],
) -> Result<Vec<SweepRow>> {
    run_sweep_jobs(base, param, values, methods, 1)
}

/// Parallel sweep: run the (value × method) grid across `jobs` OS
/// threads. Cell results are bit-identical to `jobs = 1` (see the module
/// docs for the three rules that guarantee it); only wall-clock changes.
pub fn run_sweep_jobs(
    base: &Config,
    param: &str,
    values: &[String],
    methods: &[Method],
    jobs: usize,
) -> Result<Vec<SweepRow>> {
    // ---- build every cell config up front, in grid order ---------------
    let jobs = jobs.max(1);
    let mut cells: Vec<(String, Method, Config)> = Vec::new();
    for value in values {
        for &method in methods {
            let mut cfg = base.clone();
            cfg.algo.method = method;
            cfg.name = format!("{}_{}={}_{}", base.name, param, value, method.as_str());
            cfg.apply_override(&format!("{param}={value}"))
                .with_context(|| format!("sweep value {value:?}"))?;
            // derived per-value seed; method arms share it (seed-paired
            // comparison — see the module docs)
            cfg.seed = derive_seed(base.seed, &format!("{param}={value}"));
            if jobs > 1 {
                // concurrent cells own the thread budget: in-run worker
                // pools on top would oversubscribe the cores. Serial
                // grids (jobs == 1) keep the base config's run.threads.
                // Either way the payload is bit-identical (DESIGN.md §6).
                cfg.run.threads = 1;
            }
            let cfg = resolve_policy(&cfg);
            cfg.validate()?;
            cells.push((value.clone(), method, cfg));
        }
    }

    // ---- fan out on the shared pool, ordered collection -----------------
    run_cells(
        jobs,
        cells
            .into_iter()
            .map(|(value, method, cfg)| move || run_cell(value, method, cfg))
            .collect(),
    )
    .into_iter()
    .collect()
}

/// Execute one prepared cell (shared by the serial and parallel paths).
fn run_cell(value: String, method: Method, cfg: Config) -> Result<SweepRow> {
    crate::info!("sweep: {}", cfg.name);
    let wall0 = std::time::Instant::now();
    let engine = build_engine(&cfg)?;
    let mut coord = Coordinator::new(cfg, engine)?;
    let result = coord.run()?;
    Ok(SweepRow {
        value,
        method,
        result,
        mean_batch: coord.recorder.mean_batch(),
        wall_s: wall0.elapsed().as_secs_f64(),
    })
}

/// Render sweep rows as an aligned text table (also used by the CLI).
pub fn format_table(param: &str, rows: &[SweepRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:<10} {:>10} {:>10} {:>8} {:>12} {:>10} {:>11} {:>8}\n",
        param, "method", "best_ppl", "final_ppl", "comms", "samples", "vtime_s", "mean_batch",
        "wall_s"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:<10} {:>10.4} {:>10.4} {:>8} {:>12} {:>10.3} {:>11.1} {:>8.3}\n",
            r.value,
            r.method.as_str(),
            r.result.best_ppl,
            r.result.final_ppl,
            r.result.comm_count,
            r.result.total_samples,
            r.result.virtual_time_s,
            r.mean_batch,
            r.wall_s,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn eta_sweep_runs_and_orders() {
        let mut base = presets::quick();
        base.algo.outer_steps = 2;
        base.algo.inner_steps = 5;
        let rows = run_sweep(
            &base,
            "algo.batching.eta",
            &["0.4".into(), "1.6".into()],
            &[Method::AdLoCo],
        )
        .unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.result.best_ppl.is_finite());
        }
        // smaller eta => stricter test => larger requested batches
        // (can only be checked weakly on a short run: at minimum the
        // sweep must produce distinct configurations)
        assert_ne!(rows[0].value, rows[1].value);
        let table = format_table("eta", &rows);
        assert!(table.contains("0.4") && table.contains("1.6"));
    }

    #[test]
    fn sweep_crosses_methods() {
        let mut base = presets::quick();
        base.algo.outer_steps = 2;
        base.algo.inner_steps = 4;
        let rows = run_sweep(
            &base,
            "algo.inner_steps",
            &["3".into()],
            &[Method::AdLoCo, Method::DiLoCo],
        )
        .unwrap();
        assert_eq!(rows.len(), 2);
        assert_ne!(rows[0].method, rows[1].method);
    }

    #[test]
    fn bad_param_is_error() {
        let base = presets::quick();
        assert!(run_sweep(&base, "algo.method", &["bogus".into()], &[Method::AdLoCo]).is_err());
    }

    #[test]
    fn parallel_jobs_match_serial_rows() {
        // ordered collection + derived per-cell seeds: the grid's payload
        // must be bit-identical no matter how many threads run it
        let mut base = presets::quick();
        base.algo.outer_steps = 2;
        base.algo.inner_steps = 4;
        let values: Vec<String> = vec!["0.4".into(), "0.8".into(), "1.6".into()];
        let methods = [Method::AdLoCo, Method::DiLoCo];
        let serial =
            run_sweep_jobs(&base, "algo.batching.eta", &values, &methods, 1).unwrap();
        let parallel =
            run_sweep_jobs(&base, "algo.batching.eta", &values, &methods, 4).unwrap();
        assert_eq!(serial.len(), 6);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(parallel.iter()) {
            assert_eq!(a.value, b.value, "row order must be grid order");
            assert_eq!(a.method, b.method);
            assert_eq!(a.result.best_ppl.to_bits(), b.result.best_ppl.to_bits());
            assert_eq!(a.result.final_ppl.to_bits(), b.result.final_ppl.to_bits());
            assert_eq!(a.result.total_samples, b.result.total_samples);
            assert_eq!(a.result.comm_count, b.result.comm_count);
            assert_eq!(
                a.result.virtual_time_s.to_bits(),
                b.result.virtual_time_s.to_bits()
            );
        }
    }

    #[test]
    fn values_get_distinct_seeds_methods_stay_paired() {
        // different sweep values -> different derived seeds; a no-op
        // override value leaves the config identical except the seed,
        // so equal results would mean the derivation collapsed
        let mut base = presets::quick();
        base.algo.outer_steps = 1;
        base.algo.inner_steps = 3;
        // checkpoint_every is numerically inert while checkpoint_path is
        // None, so the two cells differ ONLY by their derived seed
        let rows = run_sweep_jobs(
            &base,
            "run.checkpoint_every",
            &["5".into(), "7".into()],
            &[Method::DiLoCo],
            2,
        )
        .unwrap();
        assert_eq!(rows.len(), 2);
        assert_ne!(
            rows[0].result.best_ppl.to_bits(),
            rows[1].result.best_ppl.to_bits(),
            "distinct values must not share a seed"
        );
        // method arms at one value share the derived seed (seed-paired
        // comparison): identical-policy methods see identical data
        assert_eq!(
            crate::util::derive_seed(base.seed, "x=1"),
            crate::util::derive_seed(base.seed, "x=1")
        );
    }
}
