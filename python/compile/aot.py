"""AOT export: lower the L2/L1 programs to HLO text + metadata for Rust.

Run once at build time (`make artifacts`).  For each profile this emits,
under artifacts/<profile>/:

    train_step_b{B}.hlo.txt   one per batch-size ladder rung B
    grad_step_b{B}.hlo.txt    SwitchMode micro-step, one per rung (nodes
                              with small memory budgets accumulate at a
                              rung below the engine max)
    apply_update.hlo.txt      SwitchMode commit (AdamW with external grad)
    eval_step_b{B}.hlo.txt    validation loss at the eval batch size
    init_params.f32.bin       flat f32 (little-endian) initial parameters
    meta.json                 layout + ladder + shapes + hyperparameters

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Python never runs after this step: the Rust binary loads the artifacts and
is self-contained.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M

# ---------------------------------------------------------------------------
# Profiles: the model sizes this repo ships. `tiny` drives tests and the
# coordination benches; `small` is the end-to-end example model.  DESIGN.md
# §4 documents the width substitution vs the paper's MicroLlama-300M.
# ---------------------------------------------------------------------------

PROFILES = {
    "tiny": dict(
        cfg=M.ModelConfig(vocab=256, d_model=64, n_layers=2, n_heads=4, seq_len=64),
        ladder=[1, 2, 4, 8, 16],
        max_chunks=4,
        eval_batch=8,
        init_seed=1,
    ),
    "small": dict(
        cfg=M.ModelConfig(vocab=512, d_model=128, n_layers=4, n_heads=4, seq_len=128),
        ladder=[1, 2, 4, 8, 16, 32],
        max_chunks=8,
        eval_batch=16,
        init_seed=1,
    ),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def chunks_for(batch: int, max_chunks: int) -> int:
    """Largest power-of-two divisor of `batch` capped at max_chunks."""
    c = 1
    while c * 2 <= max_chunks and batch % (c * 2) == 0:
        c *= 2
    return c


def export_profile(name: str, out_root: str, verbose: bool = True) -> dict:
    prof = PROFILES[name]
    cfg: M.ModelConfig = prof["cfg"]
    layout = M.ParamLayout.build(cfg)
    out_dir = os.path.join(out_root, name)
    os.makedirs(out_dir, exist_ok=True)

    p = layout.total
    s1 = cfg.seq_len + 1
    files = {}

    def emit(fname: str, fn, *specs, donate=()):
        # donate_argnums adds input_output_alias to the HLO: PJRT reuses
        # the (freshly-uploaded, never-reread) input buffers for the big
        # outputs instead of allocating new ones (EXPERIMENTS.md §Perf).
        lowered = jax.jit(fn, donate_argnums=donate).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        files[fname] = {
            "bytes": len(text),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        if verbose:
            print(f"  {fname}: {len(text)} chars", file=sys.stderr)

    flat_s = _spec((p,))
    step_s = _spec((1,))
    lr_s = _spec((1,))

    rungs = []
    for b in prof["ladder"]:
        c = chunks_for(b, prof["max_chunks"])
        tok_s = _spec((b, s1), jnp.int32)
        emit(
            f"train_step_b{b}.hlo.txt",
            functools.partial(M.train_step, cfg=cfg, chunks=c),
            flat_s, flat_s, flat_s, step_s, lr_s, tok_s,
            donate=(0, 1, 2),
        )
        rungs.append({"batch": b, "chunks": c, "file": f"train_step_b{b}.hlo.txt"})

    grad_rungs = []
    for b in prof["ladder"]:
        c = chunks_for(b, prof["max_chunks"])
        emit(
            f"grad_step_b{b}.hlo.txt",
            functools.partial(M.grad_step, cfg=cfg, chunks=c),
            flat_s, _spec((b, s1), jnp.int32),
        )
        grad_rungs.append({"batch": b, "chunks": c, "file": f"grad_step_b{b}.hlo.txt"})
    b_max = prof["ladder"][-1]
    c_max = chunks_for(b_max, prof["max_chunks"])
    emit(
        "apply_update.hlo.txt",
        functools.partial(M.apply_update, cfg=cfg),
        flat_s, flat_s, flat_s, step_s, lr_s, flat_s,
        donate=(0, 1, 2),
    )
    eb = prof["eval_batch"]
    emit(
        f"eval_step_b{eb}.hlo.txt",
        functools.partial(M.eval_step, cfg=cfg),
        flat_s, _spec((eb, s1), jnp.int32),
    )

    init = M.init_params(cfg, seed=prof["init_seed"])
    init_path = os.path.join(out_dir, "init_params.f32.bin")
    init.astype("<f4").tofile(init_path)

    meta = {
        "profile": name,
        "format_version": 1,
        "model": {k: getattr(cfg, k) for k in (
            "vocab", "d_model", "n_layers", "n_heads", "seq_len",
            "beta1", "beta2", "eps", "weight_decay", "rope_theta")},
        "d_head": cfg.d_head,
        "d_ffn": cfg.d_ffn,
        "param_count": p,
        "layout": layout.to_json_obj(),
        "ladder": rungs,
        "grad_step": {"batch": b_max, "chunks": c_max,
                      "file": f"grad_step_b{b_max}.hlo.txt"},
        "grad_steps": grad_rungs,
        "apply_update": {"file": "apply_update.hlo.txt"},
        "eval": {"batch": eb, "file": f"eval_step_b{eb}.hlo.txt"},
        "init_params": {"file": "init_params.f32.bin", "seed": prof["init_seed"],
                        "sha256": hashlib.sha256(init.tobytes()).hexdigest()[:16]},
        "tokens_shape_note": "token inputs are i32[batch, seq_len+1]",
        "scalar_outputs_note": "loss/s1/sigma2/ip_var are f32[1]",
        "files": files,
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    if verbose:
        print(f"profile {name}: {p} params, {len(files)} programs -> {out_dir}",
              file=sys.stderr)
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root dir")
    ap.add_argument("--profiles", default="tiny,small",
                    help="comma-separated profile names")
    ap.add_argument("--stamp", default=None,
                    help="write a stamp file when done (Makefile freshness)")
    args = ap.parse_args()
    for name in args.profiles.split(","):
        name = name.strip()
        if name not in PROFILES:
            raise SystemExit(f"unknown profile {name!r}; have {sorted(PROFILES)}")
        export_profile(name, args.out)
    if args.stamp:
        with open(args.stamp, "w") as f:
            f.write("ok\n")


if __name__ == "__main__":
    main()
