"""L2: MicroLlama-style decoder transformer + AdamW inner step, in pure JAX.

This module defines everything the Rust coordinator executes through PJRT:

  * a decoder-only transformer (RMSNorm, SwiGLU MLP, RoPE, causal attention
    via the L1 Pallas kernel) — the same architecture family as the
    MicroLlama model the paper trains (DESIGN.md §4 records the width
    substitution);
  * next-token cross-entropy loss;
  * chunked gradient computation feeding the L1 `grad_stats` kernel, which
    yields the norm-test / inner-product-test statistics (paper Eqs. 8-12);
  * a fused AdamW inner-optimizer step (the paper's inner optimizer).

Parameter convention: ALL parameters cross the Rust<->PJRT boundary as one
flat f32 vector (see DESIGN.md §Flat parameter convention).  `ParamLayout`
records the (name, shape, offset) table that is serialized into
artifacts/<profile>/meta.json so the Rust side can interpret the vector.

Nothing here runs at serving/training time on the Python side: `aot.py`
lowers these functions to HLO text once, and the Rust runtime executes them.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.attention import attention
from .kernels.grad_stats import grad_stats


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture + optimizer hyperparameters baked into artifacts."""

    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    seq_len: int = 64
    # AdamW (inner optimizer; paper uses AdamW with lr 4e-4 / 2e-5, wd 0.1)
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    rope_theta: float = 10000.0

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ffn(self) -> int:
        # SwiGLU sizing: 8/3 * d_model, rounded up to a multiple of 8.
        return ((8 * self.d_model // 3) + 7) // 8 * 8


# ---------------------------------------------------------------------------
# Parameter layout / flat-vector packing
# ---------------------------------------------------------------------------


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) table. Order defines flat offsets."""
    spec: List[Tuple[str, Tuple[int, ...]]] = [("embed", (cfg.vocab, cfg.d_model))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "ln_attn", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln_mlp", (cfg.d_model,)),
            (p + "w_gate", (cfg.d_model, cfg.d_ffn)),
            (p + "w_up", (cfg.d_model, cfg.d_ffn)),
            (p + "w_down", (cfg.d_ffn, cfg.d_model)),
        ]
    spec.append(("ln_final", (cfg.d_model,)))
    return spec


@dataclasses.dataclass(frozen=True)
class ParamLayout:
    names: Tuple[str, ...]
    shapes: Tuple[Tuple[int, ...], ...]
    offsets: Tuple[int, ...]
    total: int

    @staticmethod
    def build(cfg: ModelConfig) -> "ParamLayout":
        spec = param_spec(cfg)
        names, shapes, offsets = [], [], []
        off = 0
        for name, shape in spec:
            names.append(name)
            shapes.append(shape)
            offsets.append(off)
            off += int(np.prod(shape))
        return ParamLayout(tuple(names), tuple(shapes), tuple(offsets), off)

    def to_json_obj(self) -> dict:
        return {
            "total": self.total,
            "entries": [
                {"name": n, "shape": list(s), "offset": o}
                for n, s, o in zip(self.names, self.shapes, self.offsets)
            ],
        }


def unflatten(flat: jnp.ndarray, layout: ParamLayout) -> Dict[str, jnp.ndarray]:
    """Static-offset slicing of the flat vector into named tensors."""
    out = {}
    for name, shape, off in zip(layout.names, layout.shapes, layout.offsets):
        n = int(np.prod(shape))
        out[name] = jax.lax.slice(flat, (off,), (off + n,)).reshape(shape)
    return out


def init_params(cfg: ModelConfig, seed: int = 0) -> np.ndarray:
    """GPT-2-style init, returned as the flat f32 vector (numpy, host-side)."""
    layout = ParamLayout.build(cfg)
    rng = np.random.default_rng(seed)
    flat = np.empty(layout.total, dtype=np.float32)
    resid_scale = 0.02 / math.sqrt(2.0 * cfg.n_layers)
    for name, shape, off in zip(layout.names, layout.shapes, layout.offsets):
        n = int(np.prod(shape))
        if name.endswith(("ln_attn", "ln_mlp", "ln_final")):
            vals = np.ones(n, dtype=np.float32)
        elif name.endswith(("wo", "w_down")):
            vals = rng.normal(0.0, resid_scale, n).astype(np.float32)
        else:
            vals = rng.normal(0.0, 0.02, n).astype(np.float32)
        flat[off : off + n] = vals
    return flat


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def _rope_tables(cfg: ModelConfig) -> Tuple[np.ndarray, np.ndarray]:
    """Precomputed RoPE cos/sin tables, baked as constants into the HLO."""
    dh = cfg.d_head
    inv = 1.0 / (cfg.rope_theta ** (np.arange(0, dh, 2, dtype=np.float64) / dh))
    t = np.arange(cfg.seq_len, dtype=np.float64)
    freqs = np.outer(t, inv)  # [S, dh/2]
    return np.cos(freqs).astype(np.float32), np.sin(freqs).astype(np.float32)


def _apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [B, S, H, dh]; rotate pairs (even, odd) along dh."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    r1 = x1 * c - x2 * s
    r2 = x1 * s + x2 * c
    # interleave back
    out = jnp.stack([r1, r2], axis=-1)
    return out.reshape(x.shape)


def forward(flat: jnp.ndarray, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Logits [B, S, V] for input token ids [B, S] (int32)."""
    layout = ParamLayout.build(cfg)
    p = unflatten(flat, layout)
    cos, sin = _rope_tables(cfg)
    cos, sin = jnp.asarray(cos), jnp.asarray(sin)

    b, s = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head
    x = jnp.take(p["embed"], tokens, axis=0)  # [B, S, D]

    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        hn = _rmsnorm(x, p[pre + "ln_attn"])
        q = (hn @ p[pre + "wq"]).reshape(b, s, h, dh)
        k = (hn @ p[pre + "wk"]).reshape(b, s, h, dh)
        v = (hn @ p[pre + "wv"]).reshape(b, s, h, dh)
        q = _apply_rope(q, cos, sin)
        k = _apply_rope(k, cos, sin)
        # [B, S, H, dh] -> [B*H, S, dh] for the Pallas kernel
        qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
        kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
        vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
        of = attention(qf, kf, vf)
        o = of.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        x = x + o @ p[pre + "wo"]

        hn = _rmsnorm(x, p[pre + "ln_mlp"])
        gate = jax.nn.silu(hn @ p[pre + "w_gate"])
        up = hn @ p[pre + "w_up"]
        x = x + (gate * up) @ p[pre + "w_down"]

    x = _rmsnorm(x, p["ln_final"])
    return x @ p["embed"].T  # tied output head


def loss_fn(flat: jnp.ndarray, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Mean next-token cross-entropy over a [B, S+1] token batch."""
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    logits = forward(flat, inp, cfg)  # [B, S, V]
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


# ---------------------------------------------------------------------------
# Chunked gradients + adaptive-batching statistics
# ---------------------------------------------------------------------------


def chunked_grads(flat: jnp.ndarray, tokens: jnp.ndarray, cfg: ModelConfig, chunks: int):
    """Per-chunk mean losses/gradients via lax.map (keeps HLO size flat).

    tokens: [B, S+1] with B % chunks == 0.  Returns (losses [C], G [C, P]).
    """
    b = tokens.shape[0]
    assert b % chunks == 0, (b, chunks)
    grouped = tokens.reshape(chunks, b // chunks, tokens.shape[1])

    def one(chunk_tokens):
        return jax.value_and_grad(loss_fn)(flat, chunk_tokens, cfg)

    losses, grads = jax.lax.map(one, grouped)
    return losses, grads


def step_stats(grads: jnp.ndarray, chunks: int, batch: int):
    """(grad_sq_norm, sigma2_sample, ip_var_sample) via the L1 stats kernel.

    Chunk-to-sample scaling per DESIGN.md §Gradient-variance statistics:
    Var_c(g_c) = sigma2_sample / chunk_size  =>  sigma2_sample = (B/C) * ...
    """
    s1, s2, ip = grad_stats(grads)
    if chunks > 1:
        scale = batch / chunks
        sigma2 = scale * s2 / (chunks - 1)
        ip_var = scale * jnp.sum((ip - jnp.mean(ip)) ** 2) / (chunks - 1)
    else:
        sigma2 = jnp.zeros((), jnp.float32)
        ip_var = jnp.zeros((), jnp.float32)
    return s1, sigma2, ip_var


# ---------------------------------------------------------------------------
# AdamW inner step + exported entry points
# ---------------------------------------------------------------------------


def adamw_update(flat, m, v, grad, step, lr, cfg: ModelConfig):
    """One fused AdamW step. `step` is the 1-based step count as f32[1]."""
    t = step[0]
    b1, b2 = cfg.beta1, cfg.beta2
    m_new = b1 * m + (1.0 - b1) * grad
    v_new = b2 * v + (1.0 - b2) * grad * grad
    m_hat = m_new / (1.0 - b1**t)
    v_hat = v_new / (1.0 - b2**t)
    upd = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * flat
    return flat - lr[0] * upd, m_new, v_new


def train_step(flat, m, v, step, lr, tokens, *, cfg: ModelConfig, chunks: int):
    """Full inner step: chunked grads -> stats kernel -> AdamW.

    Returns (new_flat, new_m, new_v, loss, grad_sq_norm, sigma2, ip_var),
    scalars packed as f32[1] so the Rust side reads uniform array literals.
    """
    batch = tokens.shape[0]
    losses, grads = chunked_grads(flat, tokens, cfg, chunks)
    gbar = jnp.mean(grads, axis=0)
    s1, sigma2, ip_var = step_stats(grads, chunks, batch)
    new_flat, new_m, new_v = adamw_update(flat, m, v, gbar, step, lr, cfg)
    pack = lambda x: jnp.reshape(x, (1,)).astype(jnp.float32)
    return (
        new_flat,
        new_m,
        new_v,
        pack(jnp.mean(losses)),
        pack(s1),
        pack(sigma2),
        pack(ip_var),
    )


def grad_step(flat, tokens, *, cfg: ModelConfig, chunks: int):
    """SwitchMode micro-step: gradient + stats only (no update applied).

    Returns (gbar, loss, grad_sq_norm, sigma2, ip_var).
    """
    batch = tokens.shape[0]
    losses, grads = chunked_grads(flat, tokens, cfg, chunks)
    gbar = jnp.mean(grads, axis=0)
    s1, sigma2, ip_var = step_stats(grads, chunks, batch)
    pack = lambda x: jnp.reshape(x, (1,)).astype(jnp.float32)
    return gbar, pack(jnp.mean(losses)), pack(s1), pack(sigma2), pack(ip_var)


def apply_update(flat, m, v, step, lr, grad, *, cfg: ModelConfig):
    """SwitchMode commit: AdamW with an externally-accumulated gradient."""
    return adamw_update(flat, m, v, grad, step, lr, cfg)


def eval_step(flat, tokens, *, cfg: ModelConfig):
    """Validation loss over a [B, S+1] batch, as f32[1]."""
    return (jnp.reshape(loss_fn(flat, tokens, cfg), (1,)),)
