"""Pallas fused chunk-gradient moment kernel — the adaptive-batching hot spot.

The norm test (paper Eq. 10) and inner-product test (Eq. 12) need, per
optimizer step, three statistics over the C per-chunk mean gradients
g_0..g_{C-1} (each of length P = parameter count):

    s1 = ||gbar||^2            with gbar = mean_c g_c
    s2 = sum_c ||g_c - gbar||^2
    ip = [<g_c, gbar>]_c

Computed naively these need several O(C*P) passes and materialize the
(C, P) residual matrix.  This kernel fuses all three into a single pass:
the grid tiles the parameter axis into `block_p`-wide stripes, each
program loads one (C, block_p) stripe into VMEM, forms the stripe's gbar
once, and accumulates the three reductions into tiny output refs shared
by every grid step (index_map -> 0, initialized at program 0).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the GPU formulation
would be a grid-stride loop with atomics into global accumulators; on TPU
the sequential grid makes the accumulation race-free by construction, and
`block_p` is sized so the stripe (C * block_p * 4B, C <= 16) stays a few
hundred KiB — deep inside VMEM with room for double buffering.

Runs with interpret=True (CPU PJRT); see attention.py for why.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_P = 8192


def _stats_kernel(g_ref, s1_ref, s2_ref, ip_ref):
    """One parameter-stripe program: accumulate the three moments."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        s1_ref[0] = 0.0
        s2_ref[0] = 0.0
        ip_ref[:] = jnp.zeros_like(ip_ref)

    g = g_ref[...]  # [C, block_p]
    gbar = jnp.mean(g, axis=0)  # [block_p]
    s1_ref[0] += jnp.sum(gbar * gbar)
    diff = g - gbar[None, :]
    s2_ref[0] += jnp.sum(diff * diff)
    ip_ref[:] += g @ gbar  # [C]


def grad_stats(g: jnp.ndarray, block_p: int = DEFAULT_BLOCK_P):
    """Fused (s1, s2, ip) over stacked chunk gradients g: [C, P].

    P is zero-padded up to a multiple of `block_p`; zero columns are exact
    no-ops for all three statistics (gbar = 0 there), so padding does not
    perturb the result.
    """
    c, p = g.shape
    block_p = min(block_p, _next_multiple(p, 128))
    p_pad = _next_multiple(p, block_p)
    if p_pad != p:
        g = jnp.pad(g, ((0, 0), (0, p_pad - p)))
    grid = (p_pad // block_p,)
    s1, s2, ip = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((c, block_p), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
        ],
        interpret=True,
    )(g.astype(jnp.float32))
    return s1[0], s2[0], ip


def _next_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("chunks", "batch"))
def batch_stats(g: jnp.ndarray, chunks: int, batch: int):
    """Convenience wrapper returning the paper-level statistics.

    Returns (grad_sq_norm, sigma2_sample, ip_var_sample):
      grad_sq_norm  = ||gbar||^2                        (Eq. 10 denominator)
      sigma2_sample ~= Var_i(grad_i)    via chunk scaling: (B/C) * s2/(C-1)
      ip_var_sample ~= Var_i(<grad_i, gbar>)          = (B/C) * Var_c(ip_c)
    For chunks == 1 the variances are returned as 0; the Rust controller
    substitutes its EMA fallback (rust/src/batching).
    """
    s1, s2, ip = grad_stats(g)
    if chunks > 1:
        scale = batch / chunks
        sigma2 = scale * s2 / (chunks - 1)
        ip_var = scale * jnp.sum((ip - jnp.mean(ip)) ** 2) / (chunks - 1)
    else:
        sigma2 = jnp.asarray(0.0, jnp.float32)
        ip_var = jnp.asarray(0.0, jnp.float32)
    return s1, sigma2, ip_var
