"""Pallas tiled causal attention (forward flash-style, custom-VJP backward).

This is the L1 compute hot-spot of the transformer used by the AdLoCo
reproduction.  The forward pass is written in the FlashAttention schedule:
the grid iterates over (batch*heads, query blocks), each program keeps an
online-softmax accumulator in VMEM-sized registers and streams key/value
blocks, so the S x S score matrix is never materialized.  The log-sum-exp
per query row is emitted as a second output and reused by the backward
kernel, which recomputes the probabilities blockwise.

TPU adaptation notes (paper targets A100 CUDA; see DESIGN.md
§Hardware-Adaptation):
  * the threadblock tiling of GPU flash attention becomes BlockSpec-driven
    HBM->VMEM streaming: one (block_q x dh) query tile resident, key/value
    tiles streamed via `pl.dynamic_slice`-style loads inside a fori_loop;
  * the matmuls are shaped (block_q x dh) @ (dh x block_k) to feed the MXU
    with contiguous lanes (dh is the minor dimension everywhere);
  * everything below runs with interpret=True on CPU PJRT — real-TPU
    lowering would emit a Mosaic custom call the CPU plugin cannot execute
    (see /opt/xla-example/README.md).

Shapes: q, k, v are [BH, S, dh] with BH = batch * heads.  S must be a
multiple of the query block; dh is small (<= 128) and kept whole.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 64 x 64 f32 score tiles keep the working set
# (q tile + 2 kv tiles + accumulator ~= 4 * 64 * 128 * 4B ~= 128 KiB)
# far inside a TPU core's ~16 MiB VMEM even with double buffering.
DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64

_NEG_INF = -1e30  # finite stand-in for -inf: keeps exp() exact zero without NaNs


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_q, block_k, seq_len):
    """One (bh, q-block) program of the flash forward pass."""
    qi = pl.program_id(1)
    q = q_ref[0, :, :]  # [block_q, dh]
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # [block_q]

    # Causal bound: key block t is live iff t*block_k <= last query row.
    num_kb = (qi * block_q + block_q + block_k - 1) // block_k

    def body(t, carry):
        m_i, l_i, acc = carry
        k_blk = k_ref[0, pl.dslice(t * block_k, block_k), :]
        v_blk = v_ref[0, pl.dslice(t * block_k, block_k), :]
        s = jnp.dot(q, k_blk.T) * scale  # [block_q, block_k]
        k_pos = t * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(mask, s, _NEG_INF)

        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])  # [block_q, block_k]
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v_blk)
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, dh), dtype=jnp.float32)
    m_i, l_i, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))

    o_ref[0, :, :] = (acc / l_i[:, None]).astype(o_ref.dtype)
    lse_ref[0, :] = (m_i + jnp.log(l_i)).astype(lse_ref.dtype)


def _bwd_kernel(q_ref, k_ref, v_ref, o_ref, do_ref, lse_ref, dq_ref, dk_ref, dv_ref, *, seq_len):
    """One bh program of the backward pass.

    Recomputes the probability matrix from (q, k, lse) — the classic
    flash-backward trick — then forms dq/dk/dv with three matmuls.  The
    full S x S tile is used per program: for the sequence lengths this
    repo compiles (S <= 256, f32) that is <= 256 KiB, still VMEM-friendly,
    so blocking the backward adds no memory benefit at these shapes.
    """
    q = q_ref[0, :, :]
    k = k_ref[0, :, :]
    v = v_ref[0, :, :]
    o = o_ref[0, :, :]
    do = do_ref[0, :, :]
    lse = lse_ref[0, :]
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)

    pos = jax.lax.iota(jnp.int32, seq_len)
    mask = pos[:, None] >= pos[None, :]

    s = jnp.dot(q, k.T) * scale
    s = jnp.where(mask, s, _NEG_INF)
    p = jnp.exp(s - lse[:, None])  # softmax probabilities, exact zeros off-causal
    p = jnp.where(mask, p, 0.0)

    dv = jnp.dot(p.T, do)
    dp = jnp.dot(do, v.T)
    delta = jnp.sum(do * o, axis=-1)  # [S]
    ds = p * (dp - delta[:, None]) * scale
    dq = jnp.dot(ds, k)
    dk = jnp.dot(ds.T, q)

    dq_ref[0, :, :] = dq.astype(dq_ref.dtype)
    dk_ref[0, :, :] = dk.astype(dk_ref.dtype)
    dv_ref[0, :, :] = dv.astype(dv_ref.dtype)


def _attention_fwd_impl(q, k, v, block_q, block_k):
    bh, s, dh = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    grid = (bh, s // block_q)
    kernel = functools.partial(
        _fwd_kernel, block_q=block_q, block_k=block_k, seq_len=s
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_q), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
    return o, lse


def _attention_bwd_impl(q, k, v, o, do, lse):
    bh, s, dh = q.shape
    kernel = functools.partial(_bwd_kernel, seq_len=s)
    spec3 = pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0))
    spec1 = pl.BlockSpec((1, s), lambda i: (i, 0))
    dq, dk, dv = pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[spec3, spec3, spec3, spec3, spec3, spec1],
        out_specs=[spec3, spec3, spec3],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
            jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
            jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        ],
        interpret=True,
    )(q, k, v, o, do, lse)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def attention(q, k, v, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Causal attention over [BH, S, dh] tensors (differentiable)."""
    o, _ = _attention_fwd_impl(q, k, v, block_q, block_k)
    return o


def _attention_vjp_fwd(q, k, v, block_q, block_k):
    o, lse = _attention_fwd_impl(q, k, v, block_q, block_k)
    return o, (q, k, v, o, lse)


def _attention_vjp_bwd(block_q, block_k, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _attention_bwd_impl(q, k, v, o, do, lse)
    return dq, dk, dv


attention.defvjp(_attention_vjp_fwd, _attention_vjp_bwd)


def attention_with_lse(q, k, v, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Non-differentiable variant that also returns the log-sum-exp rows."""
    return _attention_fwd_impl(q, k, v, block_q, block_k)
