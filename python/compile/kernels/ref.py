"""Pure-jnp reference oracles for the Pallas kernels.

These are the *correctness source of truth*: every Pallas kernel in this
package has a matching function here, written in the most direct jnp style
possible (no tiling, no online softmax, no accumulation tricks), and the
pytest/hypothesis suites assert `assert_allclose(kernel(...), ref(...))`
across shape/seed sweeps.
"""

from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Causal scaled-dot-product attention, direct formulation.

    Args:
      q, k, v: float arrays of shape [BH, S, dh] (batch*heads flattened).

    Returns:
      o: [BH, S, dh] = softmax(mask(q k^T / sqrt(dh))) v
    """
    _, s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    logits = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, :, :], logits, jnp.asarray(-jnp.inf, q.dtype))
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def attention_lse_ref(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Row-wise log-sum-exp of the masked attention logits, shape [BH, S].

    Used to validate the auxiliary output the flash-style forward stores
    for the backward pass.
    """
    _, s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    logits = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, :, :], logits, jnp.asarray(-jnp.inf, q.dtype))
    m = jnp.max(logits, axis=-1)
    return m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))


def grad_stats_ref(g: jnp.ndarray):
    """Chunk-gradient moment statistics, direct formulation.

    Args:
      g: [C, P] stacked per-chunk mean gradients.

    Returns:
      (s1, s2, ip) where
        s1 = || mean_c g_c ||^2                     (scalar)
        s2 = sum_c || g_c - mean_c g_c ||^2         (scalar)
        ip = [ <g_c, mean_c g_c> for c in 0..C )    ([C])
    """
    gbar = jnp.mean(g, axis=0)
    s1 = jnp.sum(gbar * gbar)
    diff = g - gbar[None, :]
    s2 = jnp.sum(diff * diff)
    ip = g @ gbar
    return s1, s2, ip


def norm_test_batch_ref(s1, s2, chunks: int, batch: int, eta: float) -> float:
    """Requested batch size per the norm test (paper Eq. 10), reference form.

    sigma^2_sample ~= (B/C) * s2 / (C-1); b_req = ceil(sigma^2 / (eta^2 s1)).
    Mirrored by the Rust controller (rust/src/batching) — kept here so the
    python tests pin the exact formula both sides implement.
    """
    if chunks <= 1:
        return float("nan")
    sigma2 = (batch / chunks) * float(s2) / (chunks - 1)
    denom = eta * eta * float(s1)
    if denom <= 0.0:
        return float("inf")
    return math.ceil(sigma2 / denom)


def inner_product_test_batch_ref(s1, ip, chunks: int, batch: int, theta: float) -> float:
    """Requested batch size per the inner-product test (paper Eq. 12)."""
    if chunks <= 1:
        return float("nan")
    ip = jnp.asarray(ip)
    var_c = float(jnp.sum((ip - jnp.mean(ip)) ** 2)) / (chunks - 1)
    var_i = (batch / chunks) * var_c
    denom = theta * theta * float(s1) * float(s1)
    if denom <= 0.0:
        return float("inf")
    return math.ceil(var_i / denom)
