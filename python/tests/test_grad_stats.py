"""Pallas grad_stats kernel vs the pure-jnp oracle + scaling identities."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.grad_stats import grad_stats, batch_stats
from compile.kernels import ref


def _g(rng, c, p, scale=1.0):
    return jnp.asarray(rng.normal(0.0, scale, size=(c, p)), jnp.float32)


@pytest.mark.parametrize("c,p", [(2, 64), (4, 1000), (8, 50000), (16, 123)])
def test_matches_ref(c, p):
    rng = np.random.default_rng(c * 1000 + p)
    g = _g(rng, c, p)
    s1, s2, ip = grad_stats(g, block_p=4096)
    r1, r2, ri = ref.grad_stats_ref(g)
    np.testing.assert_allclose(s1, r1, rtol=2e-4)
    np.testing.assert_allclose(s2, r2, rtol=2e-4)
    np.testing.assert_allclose(ip, ri, rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("block_p", [128, 512, 4096, 1 << 20])
def test_block_size_invariance(block_p):
    """Stripe width must not change the accumulated statistics."""
    rng = np.random.default_rng(77)
    g = _g(rng, 4, 10000)
    s1a, s2a, ipa = grad_stats(g, block_p=block_p)
    r1, r2, ri = ref.grad_stats_ref(g)
    np.testing.assert_allclose(s1a, r1, rtol=2e-4)
    np.testing.assert_allclose(s2a, r2, rtol=2e-4)
    np.testing.assert_allclose(ipa, ri, rtol=2e-4, atol=1e-4)


def test_padding_is_noop():
    """P not a multiple of block_p: zero-padding must not perturb stats."""
    rng = np.random.default_rng(5)
    g = _g(rng, 3, 130)  # forces padding with block_p=128
    s1, s2, ip = grad_stats(g, block_p=128)
    r1, r2, ri = ref.grad_stats_ref(g)
    np.testing.assert_allclose(s1, r1, rtol=2e-4)
    np.testing.assert_allclose(s2, r2, rtol=2e-4)
    np.testing.assert_allclose(ip, ri, rtol=2e-4, atol=1e-4)


def test_identical_chunks_zero_variance():
    """All chunks equal => s2 == 0 and ip uniform."""
    g0 = jnp.ones((4, 256), jnp.float32) * 0.5
    s1, s2, ip = grad_stats(g0, block_p=128)
    np.testing.assert_allclose(s2, 0.0, atol=1e-6)
    np.testing.assert_allclose(s1, 256 * 0.25, rtol=1e-5)
    np.testing.assert_allclose(ip, jnp.full((4,), 256 * 0.25), rtol=1e-5)


def test_single_chunk():
    """C=1: s2 must be 0 (gbar == g0) and batch_stats returns zero variances."""
    rng = np.random.default_rng(2)
    g = _g(rng, 1, 500)
    s1, s2, ip = grad_stats(g, block_p=128)
    np.testing.assert_allclose(s2, 0.0, atol=1e-5)
    _, sigma2, ip_var = batch_stats(g, chunks=1, batch=1)
    assert float(sigma2) == 0.0 and float(ip_var) == 0.0


def test_batch_stats_scaling():
    """sigma2 must carry the (B/C) chunk-to-sample scaling (DESIGN.md)."""
    rng = np.random.default_rng(8)
    g = _g(rng, 4, 1000)
    s1, sigma2, ip_var = batch_stats(g, chunks=4, batch=32)
    _, r2, ri = ref.grad_stats_ref(g)
    np.testing.assert_allclose(sigma2, (32 / 4) * float(r2) / 3, rtol=2e-4)
    ivar = float(jnp.sum((ri - jnp.mean(ri)) ** 2)) / 3
    np.testing.assert_allclose(ip_var, (32 / 4) * ivar, rtol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    c=st.integers(1, 12),
    p=st.integers(1, 3000),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    block_pow=st.integers(7, 12),
)
def test_hypothesis_sweep(c, p, seed, scale, block_pow):
    rng = np.random.default_rng(seed)
    g = _g(rng, c, p, scale)
    s1, s2, ip = grad_stats(g, block_p=2**block_pow)
    r1, r2, ri = ref.grad_stats_ref(g)
    tol = dict(rtol=3e-4, atol=3e-4 * scale * scale * max(p, 1))
    np.testing.assert_allclose(s1, r1, **tol)
    np.testing.assert_allclose(s2, r2, **tol)
    np.testing.assert_allclose(ip, ri, **tol)


def test_norm_test_formula_reference():
    """Pin the Eq.10 arithmetic both the python oracle and Rust implement."""
    b = ref.norm_test_batch_ref(s1=2.0, s2=6.0, chunks=4, batch=16, eta=0.8)
    # sigma2 = (16/4) * 6/3 = 8; denom = 0.64 * 2 = 1.28; ceil(8/1.28) = 7
    assert b == 7


def test_inner_product_test_formula_reference():
    ip = [1.0, 2.0, 3.0, 4.0]
    b = ref.inner_product_test_batch_ref(s1=2.0, ip=ip, chunks=4, batch=16, theta=0.5)
    # var_c = 5/3; var_i = 4*5/3; denom = 0.25*4 = 1.0 -> ceil(20/3) = 7
    assert b == 7
