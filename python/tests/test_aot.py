"""AOT export path: HLO text generation, ladder metadata, artifact layout."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


def test_chunks_for():
    assert aot.chunks_for(1, 8) == 1
    assert aot.chunks_for(2, 8) == 2
    assert aot.chunks_for(4, 8) == 4
    assert aot.chunks_for(16, 8) == 8
    assert aot.chunks_for(16, 4) == 4
    assert aot.chunks_for(6, 8) == 2  # largest pow2 divisor of 6 is 2


def test_profiles_ladders_sorted_pow2():
    for name, prof in aot.PROFILES.items():
        ladder = prof["ladder"]
        assert ladder == sorted(ladder), name
        for b in ladder:
            assert b & (b - 1) == 0, f"{name}: rung {b} not a power of two"
        cfg = prof["cfg"]
        assert cfg.d_model % cfg.n_heads == 0


def test_hlo_text_is_parseable_module():
    """Lower a small program and check HLO text structure (ENTRY + tuple)."""
    cfg = M.ModelConfig(vocab=32, d_model=16, n_layers=1, n_heads=2, seq_len=8)
    layout = M.ParamLayout.build(cfg)
    import functools
    fn = functools.partial(M.eval_step, cfg=cfg)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((layout.total,), jnp.float32),
        jax.ShapeDtypeStruct((2, cfg.seq_len + 1), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[1]" in text  # tuple-packed scalar loss


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")


@pytest.mark.skipif(not os.path.isdir(ARTIFACTS), reason="run `make artifacts` first")
class TestEmittedArtifacts:
    """Validate the real artifacts/ tree that Rust consumes."""

    @pytest.fixture(scope="class")
    def meta(self):
        with open(os.path.join(ARTIFACTS, "meta.json")) as f:
            return json.load(f)

    def test_meta_param_count_matches_layout(self, meta):
        cfg = M.ModelConfig(**meta["model"])
        assert M.ParamLayout.build(cfg).total == meta["param_count"]

    def test_layout_entries_contiguous(self, meta):
        off = 0
        for e in meta["layout"]["entries"]:
            assert e["offset"] == off
            off += int(np.prod(e["shape"]))
        assert off == meta["layout"]["total"] == meta["param_count"]

    def test_all_listed_files_exist(self, meta):
        for fname in meta["files"]:
            assert os.path.isfile(os.path.join(ARTIFACTS, fname)), fname
        assert os.path.isfile(os.path.join(ARTIFACTS, meta["init_params"]["file"]))

    def test_init_params_size_and_hash(self, meta):
        import hashlib
        raw = open(os.path.join(ARTIFACTS, meta["init_params"]["file"]), "rb").read()
        assert len(raw) == 4 * meta["param_count"]
        assert hashlib.sha256(raw).hexdigest()[:16] == meta["init_params"]["sha256"]

    def test_ladder_chunk_consistency(self, meta):
        for rung in meta["ladder"]:
            assert rung["batch"] % rung["chunks"] == 0

    def test_hlo_files_have_entry(self, meta):
        for rung in meta["ladder"]:
            head = open(os.path.join(ARTIFACTS, rung["file"])).read(200000)
            assert "ENTRY" in head
