"""L2 model invariants: layout, forward, loss, chunked grads, AdamW."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M

CFG = M.ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, seq_len=16)


@pytest.fixture(scope="module")
def flat():
    return jnp.asarray(M.init_params(CFG, seed=3))


def _tokens(rng, b, cfg=CFG):
    return jnp.asarray(rng.integers(0, cfg.vocab, size=(b, cfg.seq_len + 1)), jnp.int32)


# --------------------------------------------------------------------------
# layout / packing
# --------------------------------------------------------------------------


def test_layout_offsets_contiguous():
    layout = M.ParamLayout.build(CFG)
    off = 0
    for shape, o in zip(layout.shapes, layout.offsets):
        assert o == off
        off += int(np.prod(shape))
    assert layout.total == off


def test_layout_names_unique():
    layout = M.ParamLayout.build(CFG)
    assert len(set(layout.names)) == len(layout.names)


def test_unflatten_roundtrip(flat):
    layout = M.ParamLayout.build(CFG)
    parts = M.unflatten(flat, layout)
    rebuilt = jnp.concatenate([parts[n].reshape(-1) for n in layout.names])
    np.testing.assert_array_equal(rebuilt, flat)


def test_init_deterministic():
    a = M.init_params(CFG, seed=9)
    b = M.init_params(CFG, seed=9)
    np.testing.assert_array_equal(a, b)
    c = M.init_params(CFG, seed=10)
    assert not np.array_equal(a, c)


def test_init_norm_gains_are_one():
    layout = M.ParamLayout.build(CFG)
    flat = M.init_params(CFG, seed=0)
    for name, shape, off in zip(layout.names, layout.shapes, layout.offsets):
        if "ln_" in name:
            n = int(np.prod(shape))
            np.testing.assert_array_equal(flat[off : off + n], np.ones(n, np.float32))


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------


def test_forward_shape(flat):
    rng = np.random.default_rng(0)
    toks = _tokens(rng, 3)[:, :-1]
    logits = M.forward(flat, toks, CFG)
    assert logits.shape == (3, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_near_uniform_at_init(flat):
    rng = np.random.default_rng(1)
    loss = M.loss_fn(flat, _tokens(rng, 8), CFG)
    assert abs(float(loss) - math.log(CFG.vocab)) < 0.3


def test_forward_causal(flat):
    """Changing a future token must not change earlier logits."""
    rng = np.random.default_rng(2)
    toks = _tokens(rng, 1)[:, :-1]
    l1 = M.forward(flat, toks, CFG)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % CFG.vocab)
    l2 = M.forward(flat, toks2, CFG)
    np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-5, atol=1e-5)


def test_training_reduces_loss(flat):
    """A few AdamW steps on one batch must overfit it (loss drops)."""
    rng = np.random.default_rng(4)
    toks = _tokens(rng, 4)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    f = flat
    ts = jax.jit(lambda f, m, v, s: M.train_step(
        f, m, v, s, jnp.full((1,), 1e-3, jnp.float32), toks, cfg=CFG, chunks=4))
    first = None
    for i in range(8):
        f, m, v, loss, *_ = ts(f, m, v, jnp.full((1,), float(i + 1), jnp.float32))
        if first is None:
            first = float(loss[0])
    assert float(loss[0]) < first - 0.1


# --------------------------------------------------------------------------
# chunked grads + stats
# --------------------------------------------------------------------------


def test_chunked_grads_mean_equals_full_grad(flat):
    rng = np.random.default_rng(5)
    toks = _tokens(rng, 8)
    _, grads = M.chunked_grads(flat, toks, CFG, chunks=4)
    gbar = jnp.mean(grads, axis=0)
    gfull = jax.grad(M.loss_fn)(flat, toks, CFG)
    np.testing.assert_allclose(gbar, gfull, rtol=1e-3, atol=1e-5)


def test_chunked_losses_mean_equals_full_loss(flat):
    rng = np.random.default_rng(6)
    toks = _tokens(rng, 8)
    losses, _ = M.chunked_grads(flat, toks, CFG, chunks=4)
    np.testing.assert_allclose(
        jnp.mean(losses), M.loss_fn(flat, toks, CFG), rtol=1e-5)


def test_grad_step_matches_train_step_stats(flat):
    rng = np.random.default_rng(7)
    toks = _tokens(rng, 8)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    one = jnp.ones((1,), jnp.float32)
    lr = jnp.full((1,), 1e-3, jnp.float32)
    _, _, _, loss_a, s1_a, sg_a, ip_a = M.train_step(
        flat, m, v, one, lr, toks, cfg=CFG, chunks=4)
    gbar, loss_b, s1_b, sg_b, ip_b = M.grad_step(flat, toks, cfg=CFG, chunks=4)
    np.testing.assert_allclose(loss_a, loss_b, rtol=1e-6)
    np.testing.assert_allclose(s1_a, s1_b, rtol=1e-5)
    np.testing.assert_allclose(sg_a, sg_b, rtol=1e-5)
    np.testing.assert_allclose(ip_a, ip_b, rtol=1e-5)
    # and the apply path must reproduce train_step's parameter update
    f2, m2, v2 = M.apply_update(flat, m, v, one, lr, gbar, cfg=CFG)
    f1, m1, v1, *_ = M.train_step(flat, m, v, one, lr, toks, cfg=CFG, chunks=4)
    np.testing.assert_allclose(f1, f2, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-8)
    np.testing.assert_allclose(v1, v2, rtol=1e-5, atol=1e-10)


def test_adamw_against_manual_numpy():
    """Pin the optimizer arithmetic against a plain numpy transcription."""
    cfg = CFG
    rng = np.random.default_rng(8)
    n = 100
    flat = rng.normal(size=n).astype(np.float32)
    m = rng.normal(size=n).astype(np.float32) * 0.1
    v = np.abs(rng.normal(size=n)).astype(np.float32) * 0.01
    g = rng.normal(size=n).astype(np.float32)
    t, lr = 5.0, 2e-3
    f2, m2, v2 = M.adamw_update(
        jnp.asarray(flat), jnp.asarray(m), jnp.asarray(v), jnp.asarray(g),
        jnp.asarray([t], jnp.float32), jnp.asarray([lr], jnp.float32), cfg)
    mn = cfg.beta1 * m + (1 - cfg.beta1) * g
    vn = cfg.beta2 * v + (1 - cfg.beta2) * g * g
    mh = mn / (1 - cfg.beta1**t)
    vh = vn / (1 - cfg.beta2**t)
    fn = flat - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * flat)
    np.testing.assert_allclose(f2, fn, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(m2, mn, rtol=1e-6)
    np.testing.assert_allclose(v2, vn, rtol=1e-6)


def test_eval_step_matches_loss(flat):
    rng = np.random.default_rng(9)
    toks = _tokens(rng, 4)
    (l,) = M.eval_step(flat, toks, cfg=CFG)
    np.testing.assert_allclose(l[0], M.loss_fn(flat, toks, CFG), rtol=1e-6)


def test_rope_orthogonality():
    """RoPE preserves vector norms (it is a rotation)."""
    cfg = CFG
    from compile.model import _rope_tables, _apply_rope
    cos, sin = _rope_tables(cfg)
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.normal(size=(2, cfg.seq_len, cfg.n_heads, cfg.d_head)), jnp.float32)
    xr = _apply_rope(x, jnp.asarray(cos), jnp.asarray(sin))
    np.testing.assert_allclose(
        jnp.linalg.norm(xr, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j (the defining property)."""
    cfg = M.ModelConfig(vocab=16, d_model=8, n_layers=1, n_heads=1, seq_len=32)
    from compile.model import _rope_tables, _apply_rope
    cos, sin = _rope_tables(cfg)
    rng = np.random.default_rng(11)
    qv = rng.normal(size=cfg.d_head).astype(np.float32)
    kv = rng.normal(size=cfg.d_head).astype(np.float32)
    q = jnp.tile(jnp.asarray(qv), (1, cfg.seq_len, 1, 1))
    k = jnp.tile(jnp.asarray(kv), (1, cfg.seq_len, 1, 1))
    qr = _apply_rope(q, jnp.asarray(cos), jnp.asarray(sin))[0, :, 0, :]
    kr = _apply_rope(k, jnp.asarray(cos), jnp.asarray(sin))[0, :, 0, :]
    d1 = float(qr[5] @ kr[2])   # offset 3
    d2 = float(qr[20] @ kr[17])  # offset 3
    np.testing.assert_allclose(d1, d2, rtol=1e-4)
