"""Pallas attention kernel vs the pure-jnp oracle (the CORE L1 signal)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, attention_with_lse
from compile.kernels import ref


def _rand_qkv(rng, bh, s, dh, scale=1.0):
    mk = lambda: jnp.asarray(rng.normal(0.0, scale, size=(bh, s, dh)), jnp.float32)
    return mk(), mk(), mk()


@pytest.mark.parametrize("bh,s,dh", [(1, 8, 4), (2, 64, 16), (4, 128, 32), (1, 256, 64)])
def test_forward_matches_ref(bh, s, dh):
    rng = np.random.default_rng(42 + s)
    q, k, v = _rand_qkv(rng, bh, s, dh)
    o = attention(q, k, v)
    np.testing.assert_allclose(o, ref.attention_ref(q, k, v), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq,bk", [(8, 8), (16, 32), (64, 64), (32, 16)])
def test_forward_block_shape_invariance(bq, bk):
    """Tiling must not change the numerics: every block shape agrees."""
    rng = np.random.default_rng(7)
    q, k, v = _rand_qkv(rng, 2, 64, 16)
    o = attention(q, k, v, bq, bk)
    np.testing.assert_allclose(o, ref.attention_ref(q, k, v), rtol=2e-5, atol=2e-5)


def test_lse_matches_ref():
    rng = np.random.default_rng(3)
    q, k, v = _rand_qkv(rng, 2, 64, 16)
    _, lse = attention_with_lse(q, k, v)
    np.testing.assert_allclose(lse, ref.attention_lse_ref(q, k), rtol=2e-5, atol=2e-5)


def test_causality():
    """Output at position t must not depend on tokens > t."""
    rng = np.random.default_rng(9)
    q, k, v = _rand_qkv(rng, 1, 32, 8)
    o1 = attention(q, k, v)
    k2 = k.at[:, 20:, :].set(999.0)
    v2 = v.at[:, 20:, :].set(-999.0)
    o2 = attention(q, k2, v2)
    np.testing.assert_allclose(o1[:, :20, :], o2[:, :20, :], rtol=1e-6, atol=1e-6)


def test_first_position_is_v0():
    """Row 0 attends only to itself: o[0] == v[0]."""
    rng = np.random.default_rng(11)
    q, k, v = _rand_qkv(rng, 3, 16, 8)
    o = attention(q, k, v)
    np.testing.assert_allclose(o[:, 0, :], v[:, 0, :], rtol=1e-6, atol=1e-6)


def test_gradients_match_ref():
    rng = np.random.default_rng(5)
    q, k, v = _rand_qkv(rng, 2, 32, 16)
    f = lambda q, k, v: jnp.sum(jnp.sin(attention(q, k, v)))
    fr = lambda q, k, v: jnp.sum(jnp.sin(ref.attention_ref(q, k, v)))
    gk = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)


def test_gradients_under_jit():
    """custom_vjp must survive jit + being embedded in a larger graph."""
    rng = np.random.default_rng(6)
    q, k, v = _rand_qkv(rng, 1, 16, 8)
    w = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)

    @jax.jit
    def loss(q, w):
        return jnp.sum(attention(q, k, v) @ w)

    g = jax.grad(loss)(q, w)
    gr = jax.grad(lambda q, w: jnp.sum(ref.attention_ref(q, k, v) @ w))(q, w)
    np.testing.assert_allclose(g, gr, rtol=5e-4, atol=5e-5)


@settings(max_examples=20, deadline=None)
@given(
    bh=st.integers(1, 4),
    s_pow=st.integers(2, 6),  # S in {4..64}
    dh_pow=st.integers(2, 5),  # dh in {4..32}
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 5.0]),
)
def test_hypothesis_sweep(bh, s_pow, dh_pow, seed, scale):
    """Randomized shape/scale sweep; larger scales stress the online softmax."""
    s, dh = 2**s_pow, 2**dh_pow
    rng = np.random.default_rng(seed)
    q, k, v = _rand_qkv(rng, bh, s, dh, scale)
    o = attention(q, k, v, min(16, s), min(16, s))
    np.testing.assert_allclose(o, ref.attention_ref(q, k, v), rtol=3e-4, atol=3e-4)


def test_extreme_logits_no_nan():
    """Online softmax must stay finite for large-magnitude logits."""
    q = jnp.full((1, 16, 8), 30.0, jnp.float32)
    k = jnp.full((1, 16, 8), 30.0, jnp.float32)
    v = jnp.ones((1, 16, 8), jnp.float32)
    o = attention(q, k, v)
    assert bool(jnp.all(jnp.isfinite(o)))
    np.testing.assert_allclose(o, jnp.ones_like(o), rtol=1e-5)
