#!/usr/bin/env python3
"""CI perf-regression gate over bench_results/BENCH_micro.json.

Compares the micro_hotpath artifact produced by the current build
against the committed baseline (rust/benches/baselines/micro_baseline.json)
and fails when any op's median regresses by more than the tolerance
factor (default 2x — generous on purpose: shared CI runners are noisy,
and the gate is meant to catch order-of-magnitude accidents like a
de-vectorized kernel or an accidentally quadratic loop, not 10% drift).

Structural problems are always hard failures:
  * missing/unparseable artifact,
  * no kernel row at >= 1e7 params (the ladder must reach paper scale),
  * a baseline-pinned op missing from the current artifact.

Baseline rows with ``"median_ms": null`` are advisory: the op is listed
(so its presence is still checked) but not yet pinned to a number —
they pass with a note. Pin them by copying medians from a trusted CI
run's artifact.

Usage:
  python3 scripts/perf_gate.py \
      [--current rust/bench_results/BENCH_micro.json] \
      [--baseline rust/benches/baselines/micro_baseline.json] \
      [--tolerance 2.0]
"""

import argparse
import json
import sys

KERNEL_FLOOR = 10_000_000  # the ladder must reach paper scale


def load(path, what):
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"perf gate: cannot load {what} {path}: {e}")
        sys.exit(1)


def rows_by_op(doc, path):
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        print(f"perf gate: {path} has no rows array")
        sys.exit(1)
    out = {}
    for r in rows:
        if not isinstance(r, dict) or "op" not in r:
            print(f"perf gate: malformed row in {path}: {r!r}")
            sys.exit(1)
        out[r["op"]] = r
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="rust/bench_results/BENCH_micro.json")
    ap.add_argument("--baseline", default="rust/benches/baselines/micro_baseline.json")
    ap.add_argument("--tolerance", type=float, default=2.0)
    args = ap.parse_args()

    current = rows_by_op(load(args.current, "current artifact"), args.current)
    baseline_doc = load(args.baseline, "baseline")
    baseline = rows_by_op(baseline_doc, args.baseline)

    # structural: the ladder must include a paper-scale kernel row
    big = [
        op
        for op, r in current.items()
        if isinstance(r.get("params"), (int, float)) and r["params"] >= KERNEL_FLOOR
    ]
    if not big:
        print(
            f"perf gate: FAIL — no kernel row at >= {KERNEL_FLOOR} params "
            f"in {args.current}; the micro ladder must reach paper scale"
        )
        sys.exit(1)

    failures = []
    advisory = 0
    checked = 0
    for op, base_row in baseline.items():
        cur = current.get(op)
        if cur is None:
            failures.append(f"op {op!r} pinned in baseline but missing from current artifact")
            continue
        base_med = base_row.get("median_ms")
        if base_med is None:
            advisory += 1
            continue
        cur_med = cur.get("median_ms")
        if not isinstance(cur_med, (int, float)) or cur_med < 0:
            failures.append(f"op {op!r}: current median_ms is {cur_med!r}")
            continue
        checked += 1
        if cur_med > args.tolerance * base_med:
            failures.append(
                f"op {op!r}: median {cur_med:.4f} ms > {args.tolerance}x "
                f"baseline {base_med:.4f} ms"
            )

    print(
        f"perf gate: {len(current)} current rows, {len(baseline)} baseline rows "
        f"({checked} gated, {advisory} advisory/unpinned), "
        f"{len(big)} rows at >= {KERNEL_FLOOR} params, tolerance {args.tolerance}x"
    )
    if failures:
        for f in failures:
            print(f"perf gate: FAIL — {f}")
        sys.exit(1)
    print("perf gate: PASS")


if __name__ == "__main__":
    main()
