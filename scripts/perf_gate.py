#!/usr/bin/env python3
"""CI perf-regression gate over the bench_results perf artifacts.

Compares the micro_hotpath artifact produced by the current build
against the committed baseline (rust/benches/baselines/micro_baseline.json)
and fails when any op's median regresses by more than the tolerance
factor (default 2x — generous on purpose: shared CI runners are noisy,
and the gate is meant to catch order-of-magnitude accidents like a
de-vectorized kernel or an accidentally quadratic loop, not 10% drift).

Structural problems are always hard failures:
  * missing/unparseable artifact,
  * no kernel row at >= 1e7 params (the ladder must reach paper scale),
  * a baseline-pinned op missing from the current artifact,
  * a ``round.steady`` row without the ``allocs_per_round`` /
    ``param_allocs_per_round`` / ``peak_rss_bytes`` keys (the
    allocation-tracked half of the perf trajectory, DESIGN.md §14),
  * a measured ``param_allocs_per_round`` that is not 0 — a steady-state
    round must perform zero param-sized heap allocations.

Baseline rows with ``"median_ms": null`` are advisory: the op is listed
(so its presence is still checked) but not yet pinned to a number —
they pass with a note. Pin them by copying medians from a trusted CI
run's artifact. Measured alloc counts are likewise advisory while null
(a build without ``--features perf-count-alloc``) unless
``--require-alloc-counts`` is passed, which CI does on the instrumented
leg.

When ``--fig6-current`` is given, the fig6 wall-clock trajectory is
gated the same way against rust/benches/baselines/fig6_baseline.json:
every baseline-pinned workers point must be present, and a pinned
``wall_s`` must not regress past the tolerance.

Usage:
  python3 scripts/perf_gate.py \
      [--current rust/bench_results/BENCH_micro.json] \
      [--baseline rust/benches/baselines/micro_baseline.json] \
      [--fig6-current rust/bench_results/BENCH_fig6.json] \
      [--fig6-baseline rust/benches/baselines/fig6_baseline.json] \
      [--tolerance 2.0] [--require-alloc-counts]
"""

import argparse
import json
import sys

KERNEL_FLOOR = 10_000_000  # the ladder must reach paper scale
STEADY_PREFIX = "round.steady("
ALLOC_KEYS = ("allocs_per_round", "param_allocs_per_round", "peak_rss_bytes")


def load(path, what):
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"perf gate: cannot load {what} {path}: {e}")
        sys.exit(1)


def rows_by_key(doc, path, key):
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        print(f"perf gate: {path} has no rows array")
        sys.exit(1)
    out = {}
    for r in rows:
        if not isinstance(r, dict) or key not in r:
            print(f"perf gate: malformed row in {path} (no {key!r}): {r!r}")
            sys.exit(1)
        out[r[key]] = r
    return out


def check_medians(baseline, current, tolerance, what, failures):
    """Presence + regression gate shared by the micro and fig6 legs."""
    advisory = 0
    checked = 0
    for key, base_row in baseline.items():
        cur = current.get(key)
        if cur is None:
            failures.append(
                f"{what} {key!r} pinned in baseline but missing from current artifact"
            )
            continue
        base_med = base_row.get("median_ms" if what == "op" else "wall_s")
        if base_med is None:
            advisory += 1
            continue
        field = "median_ms" if what == "op" else "wall_s"
        cur_med = cur.get(field)
        if not isinstance(cur_med, (int, float)) or cur_med < 0:
            failures.append(f"{what} {key!r}: current {field} is {cur_med!r}")
            continue
        checked += 1
        if cur_med > tolerance * base_med:
            failures.append(
                f"{what} {key!r}: {field} {cur_med:.4f} > {tolerance}x "
                f"baseline {base_med:.4f}"
            )
    return checked, advisory


def check_steady_rows(current, require_alloc_counts, failures):
    """The allocation-tracked rows (DESIGN.md §14): every round.steady op
    must carry the alloc/RSS keys; measured param-sized alloc counts
    must be exactly zero."""
    steady = [op for op in current if op.startswith(STEADY_PREFIX)]
    if not steady:
        failures.append(
            f"no {STEADY_PREFIX}...) rows in the current artifact — the "
            f"steady-round allocation trajectory is missing"
        )
        return 0
    measured = 0
    for op in steady:
        row = current[op]
        for key in ALLOC_KEYS:
            if key not in row:
                failures.append(f"op {op!r}: missing {key!r} field")
        apr = row.get("param_allocs_per_round")
        if apr is None:
            if require_alloc_counts:
                failures.append(
                    f"op {op!r}: param_allocs_per_round is null but "
                    f"--require-alloc-counts was given (bench must run with "
                    f"--features perf-count-alloc)"
                )
            continue
        measured += 1
        if apr != 0:
            failures.append(
                f"op {op!r}: param_allocs_per_round = {apr!r}, expected 0 — "
                f"a steady-state round must not heap-allocate param-sized buffers"
            )
    return measured


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="rust/bench_results/BENCH_micro.json")
    ap.add_argument("--baseline", default="rust/benches/baselines/micro_baseline.json")
    ap.add_argument("--fig6-current", default=None)
    ap.add_argument("--fig6-baseline", default="rust/benches/baselines/fig6_baseline.json")
    ap.add_argument("--tolerance", type=float, default=2.0)
    ap.add_argument(
        "--require-alloc-counts",
        action="store_true",
        help="hard-fail when the steady-round rows carry null alloc counts",
    )
    args = ap.parse_args()

    current = rows_by_key(load(args.current, "current artifact"), args.current, "op")
    baseline_doc = load(args.baseline, "baseline")
    baseline = rows_by_key(baseline_doc, args.baseline, "op")

    # structural: the ladder must include a paper-scale kernel row
    big = [
        op
        for op, r in current.items()
        if isinstance(r.get("params"), (int, float)) and r["params"] >= KERNEL_FLOOR
    ]
    if not big:
        print(
            f"perf gate: FAIL — no kernel row at >= {KERNEL_FLOOR} params "
            f"in {args.current}; the micro ladder must reach paper scale"
        )
        sys.exit(1)

    failures = []
    checked, advisory = check_medians(baseline, current, args.tolerance, "op", failures)
    measured = check_steady_rows(current, args.require_alloc_counts, failures)

    print(
        f"perf gate: {len(current)} current rows, {len(baseline)} baseline rows "
        f"({checked} gated, {advisory} advisory/unpinned, {measured} alloc-measured), "
        f"{len(big)} rows at >= {KERNEL_FLOOR} params, tolerance {args.tolerance}x"
    )

    if args.fig6_current is not None:
        fig6_cur = rows_by_key(
            load(args.fig6_current, "fig6 current artifact"), args.fig6_current, "workers"
        )
        fig6_base = rows_by_key(
            load(args.fig6_baseline, "fig6 baseline"), args.fig6_baseline, "workers"
        )
        f6_checked, f6_advisory = check_medians(
            fig6_base, fig6_cur, args.tolerance, "workers", failures
        )
        for key, row in fig6_cur.items():
            for field in ("allocs_per_round", "peak_rss_bytes"):
                if field not in row:
                    failures.append(f"workers {key!r}: missing {field!r} field")
        print(
            f"perf gate: fig6 {len(fig6_cur)} current points, {len(fig6_base)} baseline "
            f"points ({f6_checked} gated, {f6_advisory} advisory/unpinned)"
        )

    if failures:
        for f in failures:
            print(f"perf gate: FAIL — {f}")
        sys.exit(1)
    print("perf gate: PASS")


if __name__ == "__main__":
    main()
