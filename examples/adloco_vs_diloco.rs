//! AdLoCo vs DiLoCo on the *real* transformer (XLA tiny profile) — the
//! domain scenario of the paper's Figure 1, at a budget that runs in a
//! couple of minutes on CPU PJRT.
//!
//! Requires `make artifacts`. Writes eval curves to runs/.
//!
//! Run: `cargo run --release --example adloco_vs_diloco [outer] [inner]`
//! (append `--threads N` to fan each round's worker chains across N OS
//! threads — bit-identical results, shorter wall-clock; DESIGN.md §6).

use adloco::config::{presets, Method};
use adloco::coordinator::{resolve_policy, Coordinator};
use adloco::engine::build_engine;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/tiny/meta.json").exists() {
        eprintln!("artifacts/tiny missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut positional: Vec<String> = Vec::new();
    let mut threads: usize = 0;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if let Some(v) = a.strip_prefix("--threads=") {
            threads = v.parse().unwrap_or(0);
        } else if a == "--threads" {
            threads = it.next().and_then(|v| v.parse().ok()).unwrap_or(0);
        } else if !a.starts_with("--") {
            positional.push(a.clone());
        }
    }
    let outer: usize = positional.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let inner: usize = positional.get(1).and_then(|s| s.parse().ok()).unwrap_or(15);

    let mut results = Vec::new();
    for method in [Method::AdLoCo, Method::DiLoCo] {
        let mut cfg = presets::xla_tiny();
        cfg.name = format!("xla_{}", method.as_str());
        cfg.algo.method = method;
        cfg.algo.outer_steps = outer;
        cfg.algo.inner_steps = inner;
        cfg.algo.num_trainers = 3;
        cfg.algo.workers_per_trainer = 1;
        cfg.algo.merge.frequency = 2;
        cfg.algo.fixed_batch = 4;
        cfg.algo.lr_inner = 1e-3;
        cfg.run.eval_every = 5;
        cfg.run.eval_batches = 1;
        cfg.run.threads = threads;
        let cfg = resolve_policy(&cfg);

        println!("-- running {} ({outer} outer x {inner} inner) --", cfg.name);
        let engine = build_engine(&cfg)?;
        let mut coord = Coordinator::new(cfg, engine)?;
        let t0 = std::time::Instant::now();
        let r = coord.run()?;
        let wall = t0.elapsed();
        coord.recorder.write_eval_csv(&format!("runs/{}.csv", r.name))?;
        coord.recorder.write_jsonl(&format!("runs/{}.jsonl", r.name))?;

        println!(
            "   best ppl {:.2} | final ppl {:.2} | comms {} | mean batch {:.1} | {:.1}s wall",
            r.best_ppl,
            r.final_ppl,
            r.comm_count,
            coord.recorder.mean_batch(),
            wall.as_secs_f64()
        );
        results.push((r, coord.recorder.mean_batch()));
    }

    let (ad, _) = &results[0];
    let (di, _) = &results[1];
    println!("\n== AdLoCo vs DiLoCo (tiny transformer, synthetic corpus) ==");
    println!("best perplexity : adloco {:.2} vs diloco {:.2}", ad.best_ppl, di.best_ppl);
    println!(
        "virtual time    : adloco {:.2}s vs diloco {:.2}s",
        ad.virtual_time_s, di.virtual_time_s
    );
    println!(
        "samples seen    : adloco {} vs diloco {} (adaptive batches do more useful work per sync)",
        ad.total_samples, di.total_samples
    );
    println!("curves written to runs/xla_adloco.csv, runs/xla_diloco.csv");
    Ok(())
}
