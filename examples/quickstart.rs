//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Builds a config from a preset, tweaks a couple of knobs, runs AdLoCo on
//! the fast MockEngine substrate, and prints the run summary plus the
//! perplexity curve. Takes a few seconds.
//!
//! Run: `cargo run --release --example quickstart`

use adloco::config::presets;
use adloco::coordinator::Coordinator;
use adloco::engine::build_engine;

fn main() -> anyhow::Result<()> {
    // 1. Start from a preset (see `adloco presets` for the list; the
    //    paper's Table 1 lives in presets::paper_table1()).
    let mut cfg = presets::mock_default();
    cfg.name = "quickstart".into();
    cfg.algo.outer_steps = 8;
    cfg.algo.inner_steps = 20;
    cfg.algo.workers_per_trainer = 2;

    // Everything is also settable via dotted overrides, exactly like the
    // CLI's --set flags:
    cfg.apply_override("algo.batching.eta=0.8")?;
    cfg.apply_override("algo.merge.frequency=3")?;

    // Parallel runtime (DESIGN.md §6): leave run.threads at 0 ("auto":
    // the RUN_THREADS env var, else serial) or pin it explicitly, e.g.
    // `cfg.apply_override("run.threads=4")?`. Any value yields
    // bit-identical results — threads only change wall-clock.

    // 2. Build the engine (Mock here; swap the preset for `xla_tiny` to
    //    run the real PJRT transformer) and the coordinator.
    let engine = build_engine(&cfg)?;
    let mut coord = Coordinator::new(cfg, engine)?;

    // 3. Run and inspect.
    let result = coord.run()?;
    println!("== quickstart result ==");
    println!("best perplexity : {:.3}", result.best_ppl);
    println!("communications  : {} ({} bytes)", result.comm_count, result.comm_bytes);
    println!("virtual time    : {:.2}s", result.virtual_time_s);
    println!(
        "wall clock      : {:.3}s on {} thread(s)",
        result.wall_clock_s, result.threads
    );
    println!("trainers left   : {} (started with 4)", result.trainers_left);

    println!("\nperplexity curve (trainer, step, ppl):");
    for e in coord.recorder.evals.iter().step_by(4) {
        println!("  t{} step {:>4} ppl {:>10.3}", e.trainer, e.global_step, e.perplexity);
    }

    println!("\nbatch growth (first worker):");
    for s in coord
        .recorder
        .steps
        .iter()
        .filter(|s| s.trainer == 0 && s.worker == 0)
        .step_by(20)
    {
        println!(
            "  step {:>4}  requested {:>4}  executed {:>3} x{}",
            s.global_step, s.requested_batch, s.batch, s.accum_steps
        );
    }
    Ok(())
}
