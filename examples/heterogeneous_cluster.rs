//! Heterogeneous-cluster scenario — the workload the paper's introduction
//! motivates ("efficient utilization of heterogeneous hardware resources
//! ... under dynamic workloads").
//!
//! The `hetero_dynamic` preset runs the event-driven scheduler over four
//! simulated nodes with different speeds and memory budgets, plus a
//! dynamic workload: stochastic stragglers (15% of steps slowed 1.5–4x),
//! a mid-run preemption of the slow node (churn window, with data
//! re-sharded among the surviving workers) and a temporary bandwidth
//! collapse on one link. DiLoCo's fixed batch keeps every trainer —
//! including the ones pinned to weak nodes — running and idling at
//! barriers for the whole horizon; AdLoCo's merge policy consolidates the
//! weak trainers into the strong ones, so the cluster accumulates far
//! less idle time for the same training schedule.
//!
//! A second act demos the hierarchical two-level MIT topology
//! (DESIGN.md §7): the same heterogeneous nodes partitioned into two
//! groups with a slow WAN between them (`hierarchical_mit` preset) vs
//! the flat baseline — worker reduces and most merges stay on the fast
//! intra-group links, so the WAN carries strictly fewer bytes.
//!
//! Run: `cargo run --release --example heterogeneous_cluster`
//! (`-- --threads 4` fans the worker chains of each outer round across
//! 4 OS threads; results are bit-identical to serial — DESIGN.md §6).

use adloco::config::{presets, Method, TopologyKind};
use adloco::coordinator::{resolve_policy, Coordinator};
use adloco::engine::build_engine;

fn main() -> anyhow::Result<()> {
    // --threads N / RUN_THREADS, else serial (the shared bench parser)
    let threads = adloco::benchkit::threads_arg();
    let mut rows = Vec::new();
    for method in [Method::AdLoCo, Method::DiLoCo] {
        let mut cfg = presets::hetero_dynamic();
        cfg.name = format!("hetero_{}", method.as_str());
        cfg.algo.method = method;
        cfg.run.threads = threads;
        let cfg = resolve_policy(&cfg);
        let engine = build_engine(&cfg)?;
        let mut coord = Coordinator::new(cfg, engine)?;
        let r = coord.run()?;
        coord.recorder.write_eval_csv(&format!("runs/{}.csv", r.name))?;
        coord.recorder.write_jsonl(&format!("runs/{}.jsonl", r.name))?;

        println!(
            "\n-- {} : {:.3}s wall on {} thread(s) --",
            r.name, r.wall_clock_s, r.threads
        );
        println!("-- {} : per-worker utilization --", r.name);
        println!(
            "{:>7} {:>6} {:>4} {:>9} {:>9} {:>9} {:>11} {:>6}",
            "trainer", "worker", "node", "busy_s", "wait_s", "comm_s", "preempt_s", "util"
        );
        for u in &coord.recorder.utilization {
            println!(
                "{:>7} {:>6} {:>4} {:>9.3} {:>9.3} {:>9.3} {:>11.3} {:>5.1}%",
                u.trainer,
                u.worker,
                u.node,
                u.busy_s,
                u.wait_s,
                u.comm_s,
                u.preempted_s,
                u.utilization() * 100.0
            );
        }
        let tt = coord.recorder.time_to_target(8.0);
        rows.push((method, r, tt, coord.recorder.mean_batch()));
    }

    println!("\n== heterogeneous cluster under dynamic workload: AdLoCo vs DiLoCo ==");
    println!(
        "{:<10} {:>10} {:>14} {:>14} {:>10} {:>11} {:>10} {:>9}",
        "method", "best_ppl", "vtime_total_s", "vtime@tgt_s", "comms", "mean_batch", "idle_s", "util"
    );
    for (m, r, tt, mb) in &rows {
        println!(
            "{:<10} {:>10.3} {:>14.2} {:>14} {:>10} {:>11.1} {:>10.2} {:>8.1}%",
            m.as_str(),
            r.best_ppl,
            r.virtual_time_s,
            tt.map(|t| format!("{:.2}", t.1)).unwrap_or_else(|| "-".into()),
            r.comm_count,
            mb,
            r.total_idle_s,
            r.mean_utilization * 100.0
        );
    }

    let (_, adloco, _, _) = &rows[0];
    let (_, diloco, _, _) = &rows[1];
    println!(
        "\nidle time: adloco {:.2}s vs diloco {:.2}s ({})",
        adloco.total_idle_s,
        diloco.total_idle_s,
        if adloco.total_idle_s < diloco.total_idle_s {
            "AdLoCo wastes less of the cluster — MIT merging consolidates the \
             trainers stuck on weak/preempted nodes (paper §1, §4.1.2)"
        } else {
            "unexpected: DiLoCo idled less on this seed"
        }
    );

    // ---- act two: flat vs hierarchical topology (DESIGN.md §7) --------
    println!("\n== two-level MIT topology: WAN traffic, flat vs hierarchical ==");
    println!(
        "{:<14} {:>8} {:>13} {:>13} {:>10} {:>12}",
        "topology", "comms", "total_bytes", "wan_bytes", "best_ppl", "vtime_s"
    );
    let mut wan_bytes = Vec::new();
    for topology in [TopologyKind::Flat, TopologyKind::Hierarchical] {
        let mut cfg = presets::hierarchical_mit();
        cfg.name = format!("hier_mit_{}", topology.as_str());
        cfg.cluster.topology = topology;
        cfg.run.threads = threads;
        let engine = build_engine(&cfg)?;
        let mut coord = Coordinator::new(cfg, engine)?;
        let r = coord.run()?;
        println!(
            "{:<14} {:>8} {:>13} {:>13} {:>10.3} {:>12.2}",
            topology.as_str(),
            r.comm_count,
            r.comm_bytes,
            r.wan_comm_bytes,
            r.best_ppl,
            r.virtual_time_s
        );
        wan_bytes.push(r.wan_comm_bytes);
    }
    println!(
        "WAN bytes drop {:.1}x: worker reduces and same-group merges ride the \
         fast intra links; only cross-group leaders touch the WAN",
        wan_bytes[0] as f64 / wan_bytes[1].max(1) as f64
    );
    Ok(())
}
