//! Heterogeneous-cluster scenario — the workload the paper's introduction
//! motivates ("efficient utilization of heterogeneous hardware resources
//! ... under dynamic workloads").
//!
//! Four simulated nodes with different speeds and memory budgets host the
//! trainer pool. DiLoCo's fixed batch wastes the fast/large nodes and
//! stalls on the slow one; AdLoCo's per-trainer adaptive batching plus the
//! merge policy reallocates work toward the stronger trajectories, so the
//! virtual time-to-target improves.
//!
//! Run: `cargo run --release --example heterogeneous_cluster`

use adloco::config::{presets, Method, NodeConfig};
use adloco::coordinator::{resolve_policy, Coordinator};
use adloco::engine::build_engine;

fn main() -> anyhow::Result<()> {
    let mut rows = Vec::new();
    for method in [Method::AdLoCo, Method::DiLoCo] {
        let mut cfg = presets::paper_table1();
        cfg.name = format!("hetero_{}", method.as_str());
        cfg.algo.method = method;
        cfg.algo.outer_steps = 10;
        cfg.algo.inner_steps = 30;
        cfg.algo.workers_per_trainer = 2;
        cfg.algo.lr_inner = 0.02;
        cfg.algo.fixed_batch = 8;
        cfg.run.eval_every = 10;
        // a straggler-heavy cluster: one fast/big node, two mid, one slow/small
        cfg.cluster.nodes = vec![
            NodeConfig { max_batch: 128, speed: 2.0 },
            NodeConfig { max_batch: 64, speed: 1.0 },
            NodeConfig { max_batch: 64, speed: 1.0 },
            NodeConfig { max_batch: 16, speed: 0.35 },
        ];
        let cfg = resolve_policy(&cfg);
        let engine = build_engine(&cfg)?;
        let mut coord = Coordinator::new(cfg, engine)?;
        let r = coord.run()?;
        coord.recorder.write_eval_csv(&format!("runs/{}.csv", r.name))?;
        let tt = coord.recorder.time_to_target(8.0);
        rows.push((method, r, tt, coord.recorder.mean_batch()));
    }

    println!("\n== heterogeneous cluster: AdLoCo vs DiLoCo ==");
    println!(
        "{:<10} {:>10} {:>14} {:>14} {:>10} {:>11}",
        "method", "best_ppl", "vtime_total_s", "vtime@tgt_s", "comms", "mean_batch"
    );
    for (m, r, tt, mb) in &rows {
        println!(
            "{:<10} {:>10.3} {:>14.2} {:>14} {:>10} {:>11.1}",
            m.as_str(),
            r.best_ppl,
            r.virtual_time_s,
            tt.map(|t| format!("{:.2}", t.1)).unwrap_or_else(|| "-".into()),
            r.comm_count,
            mb
        );
    }
    println!("\n(adaptive batching should close the straggler gap: larger");
    println!(" batches amortize the slow node's fixed step cost, and merging");
    println!(" consolidates trainers that fall behind — paper §1, §4.1.2)");
    Ok(())
}
