//! END-TO-END driver (EXPERIMENTS.md §E2E): trains the `small` artifact
//! profile — a 4-layer MicroLlama-style transformer compiled through the
//! full L1 (Pallas) + L2 (JAX) + AOT + PJRT stack — with the complete
//! AdLoCo coordination loop (adaptive batching, merging, switch mode,
//! Nesterov outer) on the synthetic corpus, and logs the loss curve.
//!
//! This is the proof that all three layers compose: the Pallas attention
//! and grad-stats kernels execute inside every PJRT train step that the
//! Rust coordinator schedules.
//!
//! Requires `make artifacts`.
//! Run: `cargo run --release --example e2e_train [outer] [inner] [profile]`
//! Defaults: 10 outer x 30 inner = 300 inner steps on `small`.

use adloco::config::presets;
use adloco::coordinator::Coordinator;
use adloco::engine::build_engine;
use adloco::metrics::perplexity;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let outer: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(10);
    let inner: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30);
    let profile = args.get(2).cloned().unwrap_or_else(|| "small".to_string());

    if !std::path::Path::new(&format!("artifacts/{profile}/meta.json")).exists() {
        eprintln!("artifacts/{profile} missing — run `make artifacts` first");
        std::process::exit(2);
    }

    let mut cfg = if profile == "small" { presets::xla_small() } else { presets::xla_tiny() };
    cfg.name = format!("e2e_{profile}");
    cfg.algo.outer_steps = outer;
    cfg.algo.inner_steps = inner;
    cfg.algo.num_trainers = 2;
    cfg.algo.workers_per_trainer = 1;
    cfg.algo.merge.frequency = 4;
    cfg.algo.lr_inner = 6e-4;
    cfg.algo.batching.max_request = 128;
    cfg.run.eval_every = 10;
    cfg.run.eval_batches = 1;
    cfg.data.corpus_sequences = 8_000;

    let engine = build_engine(&cfg)?;
    println!("engine : {}", engine.name());
    println!(
        "run    : {} trainers x {} workers, {outer} outer x {inner} inner steps",
        cfg.algo.num_trainers, cfg.algo.workers_per_trainer
    );
    let mut coord = Coordinator::new(cfg, engine)?;
    let wall0 = std::time::Instant::now();
    let r = coord.run()?;
    let wall = wall0.elapsed();

    coord.recorder.write_eval_csv(&format!("runs/{}.csv", r.name))?;
    coord.recorder.write_jsonl(&format!("runs/{}.jsonl", r.name))?;

    println!("\n== loss curve (validation) ==");
    println!("{:>6} {:>6} {:>10} {:>12} {:>8}", "step", "outer", "loss", "ppl", "comms");
    for e in &coord.recorder.evals {
        println!(
            "{:>6} {:>6} {:>10.4} {:>12.2} {:>8}",
            e.global_step, e.outer_step, e.loss, e.perplexity, e.comm_count
        );
    }

    let first = coord.recorder.evals.first().map(|e| e.loss).unwrap_or(f64::NAN);
    let best = coord.recorder.evals.iter().map(|e| e.loss).fold(f64::INFINITY, f64::min);
    println!("\n== e2e summary ==");
    println!("wall time        : {:.1}s", wall.as_secs_f64());
    println!("inner steps      : {}", r.total_inner_steps);
    println!("loss             : {first:.4} -> {best:.4} (ppl {:.1} -> {:.1})",
        perplexity(first), perplexity(best));
    println!("communications   : {} ({:.2} MB)", r.comm_count, r.comm_bytes as f64 / 1e6);
    println!("virtual time     : {:.2}s (simulated cluster)", r.virtual_time_s);
    println!("mean batch       : {:.2}", coord.recorder.mean_batch());
    println!("trainers left    : {}", r.trainers_left);
    println!("curve written to runs/{}.csv", r.name);

    anyhow::ensure!(best < first, "e2e training failed to reduce loss");
    println!("\nOK: loss decreased through the full L1+L2+L3 stack.");
    Ok(())
}
