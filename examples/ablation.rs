//! Ablation study (the paper's Figure 2) as a runnable example: full
//! AdLoCo vs each component removed, on the MockEngine substrate so it
//! finishes in seconds. The bench `fig2_ablation` is the full version.
//!
//! Run: `cargo run --release --example ablation`
//! (append `-- --threads 4` for the parallel runtime — bit-identical
//! results, shorter wall-clock; DESIGN.md §6)

use adloco::config::{presets, Config};
use adloco::coordinator::Coordinator;
use adloco::engine::build_engine;

fn arm(
    name: &str,
    threads: usize,
    mutate: impl Fn(&mut Config),
) -> anyhow::Result<(String, f64, usize, f64, Option<f64>)> {
    let mut cfg = presets::paper_table1();
    cfg.name = format!("ablation_{name}");
    cfg.algo.outer_steps = 9;
    cfg.algo.inner_steps = 25;
    cfg.algo.workers_per_trainer = 2;
    cfg.algo.lr_inner = 0.02;
    cfg.run.eval_every = 5;
    cfg.run.threads = threads;
    for n in &mut cfg.cluster.nodes {
        n.max_batch = 16;
    }
    cfg.algo.batching.max_request = 256;
    mutate(&mut cfg);
    let engine = build_engine(&cfg)?;
    let mut coord = Coordinator::new(cfg, engine)?;
    let r = coord.run()?;
    coord.recorder.write_eval_csv(&format!("runs/{}.csv", r.name))?;
    let tt = coord.recorder.time_to_target(4.0).map(|t| t.1);
    Ok((name.to_string(), r.best_ppl, r.comm_count, coord.recorder.mean_batch(), tt))
}

fn main() -> anyhow::Result<()> {
    // `--threads N` (or RUN_THREADS) drives each arm's worker chains
    let threads = adloco::benchkit::threads_arg();
    println!("running ablation arms (paper Fig. 2)...");
    let rows = vec![
        arm("full", threads, |_| {})?,
        arm("no_adaptive", threads, |c| c.algo.batching.adaptive = false)?,
        arm("no_merge", threads, |c| c.algo.merge.enabled = false)?,
        arm("no_switch", threads, |c| c.algo.switch.enabled = false)?,
    ];
    println!(
        "\n{:<14} {:>10} {:>8} {:>11} {:>13}",
        "arm", "best_ppl", "comms", "mean_batch", "vtime@tgt_s"
    );
    for (name, ppl, comms, mb, tt) in &rows {
        println!(
            "{name:<14} {ppl:>10.3} {comms:>8} {mb:>11.1} {:>13}",
            tt.map(|t| format!("{t:.2}")).unwrap_or_else(|| "-".into())
        );
    }
    let full = rows[0].1;
    println!("\nfull AdLoCo best ppl {full:.3}; every removed component should");
    println!("degrade convergence or efficiency (paper §6.3). curves in runs/.");
    Ok(())
}
